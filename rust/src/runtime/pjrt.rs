//! The real PJRT [`Engine`] (feature `xla`): loads HLO-text artifacts and
//! executes them on a dedicated device service thread. See the module docs
//! in [`super`] for the threading model. Requires the vendored `xla`
//! crate.
//!
//! (HLO *text*, not a serialized `HloModuleProto`, because jax ≥ 0.5 emits
//! 64-bit instruction ids that the bundled xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.)

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

use super::{ArtifactInfo, TensorBuf};
use crate::config::{AccelMode, RoomyConfig};
use crate::error::{Result, RoomyError};

impl TensorBuf {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            TensorBuf::U64 { data, dims } => xla::Literal::vec1(data).reshape(dims)?,
            TensorBuf::I64 { data, dims } => xla::Literal::vec1(data).reshape(dims)?,
            TensorBuf::U32 { data, dims } => xla::Literal::vec1(data).reshape(dims)?,
            TensorBuf::I32 { data, dims } => xla::Literal::vec1(data).reshape(dims)?,
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<TensorBuf> {
        let shape = lit.array_shape()?;
        let dims = shape.dims().to_vec();
        Ok(match shape.ty() {
            xla::ElementType::U64 => TensorBuf::U64 { data: lit.to_vec()?, dims },
            xla::ElementType::S64 => TensorBuf::I64 { data: lit.to_vec()?, dims },
            xla::ElementType::U32 => TensorBuf::U32 { data: lit.to_vec()?, dims },
            xla::ElementType::S32 => TensorBuf::I32 { data: lit.to_vec()?, dims },
            other => {
                return Err(RoomyError::Xla(format!(
                    "unsupported output element type {other:?}"
                )))
            }
        })
    }
}

enum Request {
    Run {
        name: String,
        inputs: Vec<TensorBuf>,
        reply: mpsc::Sender<Result<Vec<TensorBuf>>>,
    },
    Shutdown,
}

/// PJRT engine handle: thread-safe, cheap to clone behind an `Arc`.
pub struct Engine {
    tx: mpsc::Sender<Request>,
    artifacts: HashMap<String, ArtifactInfo>,
    service: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("artifacts", &self.artifacts.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl Engine {
    /// Load the manifest from `artifacts_dir` and start the device service
    /// thread (which brings up the PJRT CPU client).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| RoomyError::io(&manifest, e))?;
        let mut artifacts = HashMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let mut cols = line.split('\t');
            let (name, file, sig) = (
                cols.next().unwrap_or_default(),
                cols.next().unwrap_or_default(),
                cols.next().unwrap_or_default(),
            );
            if name.is_empty() || file.is_empty() {
                return Err(RoomyError::InvalidArg(format!(
                    "malformed manifest line: {line:?}"
                )));
            }
            artifacts.insert(
                name.to_string(),
                ArtifactInfo {
                    name: name.to_string(),
                    path: dir.join(file),
                    signature: sig.to_string(),
                },
            );
        }

        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread_artifacts = artifacts.clone();
        let service = std::thread::Builder::new()
            .name("roomy-pjrt".into())
            .spawn(move || service_loop(thread_artifacts, rx, ready_tx))
            .map_err(|e| RoomyError::Xla(format!("failed to spawn pjrt thread: {e}")))?;
        // Wait for the client to come up so load() fails fast.
        ready_rx
            .recv()
            .map_err(|_| RoomyError::Xla("pjrt service thread died on startup".into()))??;
        Ok(Engine { tx, artifacts, service: Some(service) })
    }

    /// Resolve the engine implied by `cfg.accel`:
    /// `Rust` → `None`; `Auto` → engine iff the manifest exists; `Xla` →
    /// engine, logging a warning (and returning `None`) if unavailable.
    pub fn from_config(cfg: &RoomyConfig) -> Option<Arc<Engine>> {
        match cfg.accel {
            AccelMode::Rust => None,
            AccelMode::Xla | AccelMode::Auto => {
                let manifest = cfg.artifacts_dir.join("manifest.tsv");
                if !manifest.exists() {
                    if cfg.accel == AccelMode::Xla {
                        eprintln!(
                            "roomy: warning: AccelMode::Xla requested but {manifest:?} is \
                             missing; falling back to Rust kernels (run `make artifacts`)"
                        );
                    }
                    return None;
                }
                match Engine::load(&cfg.artifacts_dir) {
                    Ok(e) => Some(Arc::new(e)),
                    Err(e) => {
                        eprintln!(
                            "roomy: warning: failed to load XLA engine: {e}; using Rust kernels"
                        );
                        None
                    }
                }
            }
        }
    }

    /// Names of all known entry points.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Whether entry point `name` is available.
    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Execute entry point `name` with `inputs`; returns the decomposed
    /// output tuple (aot.py lowers with `return_tuple=True`). Thread-safe.
    pub fn run(&self, name: &str, inputs: Vec<TensorBuf>) -> Result<Vec<TensorBuf>> {
        if !self.has(name) {
            return Err(RoomyError::MissingArtifact { name: name.to_string() });
        }
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Run { name: name.to_string(), inputs, reply })
            .map_err(|_| RoomyError::Xla("pjrt service thread is gone".into()))?;
        rx.recv()
            .map_err(|_| RoomyError::Xla("pjrt service dropped the reply".into()))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.service.take() {
            let _ = h.join();
        }
    }
}

/// Device service: owns the (non-Send) PJRT client and compile cache.
fn service_loop(
    artifacts: HashMap<String, ArtifactInfo>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e.into()));
            return;
        }
    };
    let mut exes: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Run { name, inputs, reply } => {
                let result = run_one(&client, &artifacts, &mut exes, &name, inputs);
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    client: &xla::PjRtClient,
    artifacts: &HashMap<String, ArtifactInfo>,
    exes: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: Vec<TensorBuf>,
) -> Result<Vec<TensorBuf>> {
    if !exes.contains_key(name) {
        let info = artifacts.get(name).ok_or_else(|| RoomyError::MissingArtifact {
            name: name.to_string(),
        })?;
        let path_str = info.path.to_str().ok_or_else(|| {
            RoomyError::InvalidArg(format!("non-utf8 artifact path {:?}", info.path))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        exes.insert(name.to_string(), client.compile(&comp)?);
    }
    let exe = exes.get(name).expect("just inserted");
    let literals: Vec<xla::Literal> =
        inputs.iter().map(|b| b.to_literal()).collect::<Result<_>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?;
    let out = result
        .first()
        .and_then(|r| r.first())
        .ok_or_else(|| RoomyError::Xla("empty execution result".into()))?
        .to_literal_sync()?;
    out.to_tuple()?.iter().map(TensorBuf::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HASH_BATCH;

    /// Engine against the real artifacts dir, if built (unit-level smoke;
    /// full numeric checks live in rust/tests/integration_runtime.rs).
    fn real_engine() -> Option<Engine> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            Some(Engine::load(dir).expect("engine load"))
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses_and_unknown_name_errors() {
        let Some(e) = real_engine() else { return };
        assert!(e.has("hash_partition_k1"), "manifest should list hashpart");
        assert!(e.has("prefix_scan"));
        assert!(matches!(
            e.run("not_a_kernel", vec![]),
            Err(RoomyError::MissingArtifact { .. })
        ));
    }

    #[test]
    fn hash_partition_executes_and_matches_rust_twin() {
        let Some(e) = real_engine() else { return };
        let mut words = vec![0u64; HASH_BATCH];
        for (i, w) in words.iter_mut().enumerate() {
            *w = (i as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xABCD;
        }
        let nb = 37u64;
        let out = e
            .run(
                "hash_partition_k1",
                vec![
                    TensorBuf::u64_2d(words.clone(), HASH_BATCH, 1),
                    TensorBuf::u64_1d(vec![nb]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let mut it = out.into_iter();
        let fp = it.next().unwrap().into_u64().unwrap();
        let bucket = it.next().unwrap().into_u64().unwrap();
        for i in 0..HASH_BATCH {
            let expect_fp = crate::hashfn::fp_words(&[words[i]]);
            assert_eq!(fp[i], expect_fp, "fp mismatch at {i}");
            assert_eq!(bucket[i], crate::hashfn::bucket_of(expect_fp, nb as u32) as u64);
        }
    }

    #[test]
    fn engine_usable_from_many_threads() {
        let Some(e) = real_engine() else { return };
        let e = std::sync::Arc::new(e);
        std::thread::scope(|s| {
            for t in 0..4 {
                let e = e.clone();
                s.spawn(move || {
                    let words = vec![t as u64; HASH_BATCH];
                    let out = e
                        .run(
                            "hash_partition_k1",
                            vec![
                                TensorBuf::u64_2d(words, HASH_BATCH, 1),
                                TensorBuf::u64_1d(vec![8]),
                            ],
                        )
                        .unwrap();
                    let fp = out.into_iter().next().unwrap().into_u64().unwrap();
                    assert_eq!(fp[0], crate::hashfn::fp_words(&[t as u64]));
                });
            }
        });
    }
}
