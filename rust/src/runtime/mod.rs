//! Runtime layer: the collective execution pool and the (optional) PJRT
//! engine for AOT-compiled XLA artifacts.
//!
//! # Execution model
//!
//! Every Roomy collective — `sync`, `map`, `reduce`, external sort, shard
//! merge, BFS level expansion — decomposes into **independent per-bucket
//! tasks**: bucket `b`'s payload, op log and scratch files all live on one
//! node disk and are touched by no other bucket's task. The [`pool`]
//! module exploits that with a **locality-aware** scheduler: a
//! [`pool::WorkerPool`] of
//! [`RoomyConfig::num_workers`](crate::RoomyConfig::num_workers) scoped
//! worker threads drains **one work queue per node** (tasks tagged by the
//! shared [`Topology`](crate::cluster::Topology); worker slot
//! `n % num_workers` homes node `n`), so each worker streams its own
//! node's disk — computation follows the data, the paper's premise. What
//! an idle worker does is
//! [`RoomyConfig::steal_policy`](crate::RoomyConfig::steal_policy):
//! nothing (`off`, strict locality), one LIFO steal at a time from the
//! most-loaded queue (`bounded`, the default), or flat-cursor greed
//! (`greedy`, the pre-locality baseline). On dequeue the pool posts a
//! **cross-task prefetch hint** for the next bucket queued on the same
//! node, warming that bucket's file through the node's read-ahead lane
//! ([`crate::storage::pipeline`]) while the current bucket computes.
//!
//! Three rules make every parallel schedule **observably identical** to
//! the serial one (`num_workers = 1`, any steal policy), byte-for-byte on
//! disk:
//!
//! 1. *Bucket isolation* — a task only reads/writes files of its own
//!    bucket, so file contents depend on the task, not the schedule.
//! 2. *Deterministic merge* — per-task results (reduce partials, size
//!    deltas) are collected **by bucket index**, and folded in ascending
//!    bucket order regardless of completion order.
//! 3. *Delayed-op capture* — user functions running inside a collective
//!    (access/update callbacks, BFS `genNext`) may issue delayed ops on
//!    other structures. Those ops are captured into **per-task,
//!    per-destination spill-at-threshold logs** (scratch files under the
//!    node disks' `tmp/capture/`, so capture RAM per task is bounded by
//!    [`RoomyConfig::capture_spill_threshold`](crate::RoomyConfig::capture_spill_threshold)
//!    per destination structure the task stages into)
//!    and replayed into the destination staging buffers after the
//!    collective's barrier, ordered by (bucket index, destination, issue
//!    order) — every destination buffer receives the exact byte sequence
//!    a serial run would have produced. See
//!    [`crate::roomy::ops::StagedOps`] and the capture machinery in
//!    [`pool`].
//!
//! The pool is the seam all later scaling work hangs off. The per-node
//! queues are the topology real multi-node sharding ships on: `off`
//! already models "a worker may only touch its own node's disk", and the
//! locality / steal / queue-depth counters in
//! [`crate::metrics::PoolStats`] (plus the prefetch-hint hit/waste
//! counters in [`crate::metrics::PipelineStats`]) expose exactly the
//! load-balance behavior a cross-machine scheduler must preserve.
//!
//! # PJRT engine
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX graphs (embedding the
//! Layer-1 Pallas kernels) to **HLO text** under `artifacts/`, plus a
//! `manifest.tsv` naming each entry point. Python never runs at request
//! time — the interchange is the HLO text produced at build time. The
//! PJRT client lives behind the `xla` cargo feature (it needs the vendored
//! `xla` crate); without the feature [`Engine`] is a stub that reports
//! artifacts as unavailable and [`Engine::from_config`] always resolves to
//! the bit-exact Rust kernel fallbacks in [`crate::accel`].
//!
//! ## Threading model (feature `xla`)
//!
//! The `xla` crate's PJRT client is `Rc`-based and **not** `Send`/`Sync`,
//! but Roomy's collectives run on many pool worker threads. [`Engine`]
//! therefore runs a dedicated *device service thread* that owns the client
//! and the compile cache; callers submit [`TensorBuf`] batches over a
//! channel and block on a reply. Batches are large (thousands of elements)
//! so the channel hop is noise compared to execution.

pub mod autotune;
pub mod pool;

pub use autotune::Autotune;

use std::path::PathBuf;

use crate::error::{Result, RoomyError};

/// Batch size of the hash-partition entry points (`HASH_BATCH` in
/// `python/compile/model.py`). Rust pads partial batches to this size.
pub const HASH_BATCH: usize = 4096;
/// Batch size of `prefix_scan`.
pub const SCAN_BATCH: usize = 4096;
/// Batch size of `reduce_sumsq`.
pub const REDUCE_BATCH: usize = 4096;
/// Batch size of the `bfs_expand_n*` entry points.
pub const BFS_BATCH: usize = 1024;

/// A typed host tensor crossing the engine channel (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorBuf {
    U64 { data: Vec<u64>, dims: Vec<i64> },
    I64 { data: Vec<i64>, dims: Vec<i64> },
    U32 { data: Vec<u32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
}

impl TensorBuf {
    pub fn u64_1d(data: Vec<u64>) -> Self {
        let dims = vec![data.len() as i64];
        TensorBuf::U64 { data, dims }
    }

    pub fn i64_1d(data: Vec<i64>) -> Self {
        let dims = vec![data.len() as i64];
        TensorBuf::I64 { data, dims }
    }

    pub fn u64_2d(data: Vec<u64>, rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        TensorBuf::U64 { data, dims: vec![rows as i64, cols as i64] }
    }

    pub fn i32_2d(data: Vec<i32>, rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        TensorBuf::I32 { data, dims: vec![rows as i64, cols as i64] }
    }

    /// Unwrap as u64 data, discarding shape.
    pub fn into_u64(self) -> Result<Vec<u64>> {
        match self {
            TensorBuf::U64 { data, .. } => Ok(data),
            other => Err(RoomyError::Xla(format!("expected u64 tensor, got {other:?}"))),
        }
    }

    /// Unwrap as i64 data, discarding shape.
    pub fn into_i64(self) -> Result<Vec<i64>> {
        match self {
            TensorBuf::I64 { data, .. } => Ok(data),
            other => Err(RoomyError::Xla(format!("expected i64 tensor, got {other:?}"))),
        }
    }

    /// Unwrap as i32 data, discarding shape.
    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            TensorBuf::I32 { data, .. } => Ok(data),
            other => Err(RoomyError::Xla(format!("expected i32 tensor, got {other:?}"))),
        }
    }
}

/// One manifest row: a named AOT entry point.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    /// Shape signature string (informational; from manifest.tsv).
    pub signature: String,
}

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::Engine;

#[cfg(not(feature = "xla"))]
mod engine_stub;
#[cfg(not(feature = "xla"))]
pub use engine_stub::Engine;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AccelMode, RoomyConfig};
    use crate::testutil::tmpdir;

    #[test]
    fn missing_dir_errors() {
        assert!(Engine::load("/nonexistent/artifacts").is_err());
    }

    #[test]
    fn malformed_manifest_errors() {
        let t = tmpdir("engine_badmanifest");
        std::fs::write(t.path().join("manifest.tsv"), "onlyonecolumn\n").unwrap();
        assert!(Engine::load(t.path()).is_err());
    }

    #[test]
    fn from_config_rust_mode_is_none() {
        let mut cfg = RoomyConfig::for_testing("/tmp/x");
        cfg.accel = AccelMode::Rust;
        assert!(Engine::from_config(&cfg).is_none());
    }

    #[test]
    fn from_config_auto_without_artifacts_is_none() {
        let t = tmpdir("engine_auto");
        let mut cfg = RoomyConfig::for_testing(t.path());
        cfg.accel = AccelMode::Auto;
        cfg.artifacts_dir = t.path().join("no-artifacts");
        assert!(Engine::from_config(&cfg).is_none());
    }

    #[test]
    fn tensorbuf_unwrap_type_checks() {
        let b = TensorBuf::u64_1d(vec![1, 2, 3]);
        assert!(b.clone().into_i64().is_err());
        assert_eq!(b.into_u64().unwrap(), vec![1, 2, 3]);
    }
}
