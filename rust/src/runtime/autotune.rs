//! Counter-driven self-tuning of the I/O pipeline and prefetch distance.
//!
//! Roomy's streaming machinery has two knobs whose best setting depends
//! on the workload, not the configuration: how many chunk buffers each
//! pipelined stream circulates ([`NodeDisk::effective_depth`], seeded
//! from `io_pipeline_depth`), and how far ahead the pool's cross-task
//! prefetch hints reach ([`WorkerPool::hint_ahead`]). The [`Autotune`]
//! controller closes the loop from the metrics the runtime already
//! keeps:
//!
//! - **Pipeline depth** — per node, the growth of
//!   `reader_wait_ns + writer_wait_ns` (time collectives spent blocked
//!   on the I/O lanes, from [`crate::metrics::PipelineStats`]) since the
//!   last round. A stalling node gets one more buffer (up to the
//!   configured `io_pipeline_depth` ceiling — the controller never
//!   exceeds the RAM budget the user chose); a node whose streams never
//!   wait gives buffers back, decaying toward 1.
//! - **Hint distance** — the peak per-node task-queue depth from
//!   [`crate::metrics::PoolStats`]. Deep queues mean each dequeue can
//!   profitably warm several successors; shallow queues keep the seed's
//!   next-task-only hint.
//!
//! One `adapt` round runs **between** collectives (the cluster calls it
//! at the top of each bucket fan-out), never inside one, so a running
//! stream always keeps the depth it started with. Both knobs move *when
//! bytes move*, never *which bytes* — on-disk state stays byte-identical
//! to a run with the controller off, which the determinism suite pins.
//!
//! The controller exists only when
//! [`RoomyConfig::autotune`](crate::RoomyConfig::autotune) is enabled;
//! in the default `Off` mode the cluster holds no controller and the hot
//! path is exactly the seed's. Two inputs are available:
//!
//! - **`On`** reads the coarse end-of-collective counters (total stall
//!   nanoseconds, peak queue depth) — cheap, but a sum can't tell one
//!   10 ms stall from ten thousand 1 µs handoffs.
//! - **`Spans`** reads the latency *distributions* from
//!   [`crate::obs::hist`] instead: per-node stall p95s drive depth (a
//!   node whose typical stall is long is genuinely I/O-bound; a node
//!   with many tiny waits is not), and the skew of per-node task p95s
//!   drives the hint distance (skewed nodes mean idle workers that
//!   profit from deeper cross-task warming). `Spans` implies arming the
//!   histogram bank at `Roomy::open`.
//!
//! Spans mode additionally drives a **width policy**: the same per-node
//! task-p95 deltas reveal how many nodes actually ran work and how
//! skewed they were. When fewer nodes than workers are active under
//! severe skew, the surplus worker slots are narrowed away
//! ([`WorkerPool::set_effective_width`]) — they cannot drain the
//! straggler's FIFO-owned queue and only churn steal attempts — and
//! under extreme skew a `Bounded` steal policy is escalated to `Greedy`
//! ([`WorkerPool::set_steal_boost`]; `Off` is never escalated). Width
//! and steal aggressiveness, like depth and hints, change only *when*
//! bytes move: every width trajectory is byte-identical, which
//! `tests/determinism.rs` pins across kernels × workers × depths.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs::hist::{Domain, Hist, HistSnapshot};
use crate::runtime::pool::{WorkerPool, MAX_HINT_AHEAD};
use crate::storage::NodeDisk;

/// Pipeline stall growth per round above which a node earns one more
/// chunk buffer: 2 ms of blocked reader/writer time since the last
/// round, i.e. the collective measurably out-ran the I/O lanes.
const RAISE_STALL_NS: u64 = 2_000_000;

/// Stall growth per round below which a node gives one buffer back:
/// under 0.1 ms of waiting means the pipeline is already ahead of the
/// compute and the extra chunk RAM buys nothing.
const DECAY_STALL_NS: u64 = 100_000;

/// Spans mode: p95 stall duration this round above which a node earns a
/// buffer — the *typical* stall is half a millisecond, so the lanes are
/// genuinely behind (a counter-sum of the same magnitude could just be
/// thousands of harmless queue handoffs).
const SPANS_RAISE_P95_NS: u64 = 500_000;

/// Spans mode: p95 stall duration below which a node decays a buffer —
/// even the slow tail of its waits is a queue handoff, not I/O.
const SPANS_DECAY_P95_NS: u64 = 50_000;

/// Per-node histogram snapshots at the previous spans-mode round, so each
/// round sees only its own delta.
#[derive(Debug, Clone, Copy, Default)]
struct SpansLast {
    stall: HistSnapshot,
    task: HistSnapshot,
}

/// Spans-mode state: the histogram bank read each round plus the
/// previous round's snapshots.
#[derive(Debug)]
struct Spans {
    hist: Arc<Hist>,
    last: Mutex<Vec<SpansLast>>,
}

/// Feedback controller adapting per-node pipeline depth and the pool's
/// prefetch-hint distance from runtime counters (`On`) or latency
/// distributions (`Spans`). One per [`crate::cluster::Cluster`], present
/// only when autotune is enabled.
#[derive(Debug)]
pub struct Autotune {
    /// Per-node `reader_wait_ns + writer_wait_ns` at the previous round.
    /// Counters only grow (a metrics reset makes one delta read low —
    /// `saturating_sub` keeps that safe), so deltas are per-round stall.
    last_wait: Mutex<Vec<u64>>,
    /// Present in spans mode only.
    spans: Option<Spans>,
    rounds: AtomicU64,
    depth_raises: AtomicU64,
    depth_decays: AtomicU64,
    /// Last hint distance applied (for reporting).
    hint_ahead: AtomicUsize,
    /// Last effective pool width applied (for reporting; 0 until a
    /// round has run).
    width: AtomicUsize,
    /// Rounds that narrowed the effective width below its previous value.
    width_shrinks: AtomicU64,
    /// Rounds that widened the effective width back toward the full pool.
    width_grows: AtomicU64,
    /// Rounds that requested the Bounded→Greedy steal escalation.
    steal_boosts: AtomicU64,
}

impl Autotune {
    /// Counter-mode controller for a cluster of `nodes` node disks.
    pub fn new(nodes: usize) -> Autotune {
        Autotune {
            last_wait: Mutex::new(vec![0; nodes]),
            spans: None,
            rounds: AtomicU64::new(0),
            depth_raises: AtomicU64::new(0),
            depth_decays: AtomicU64::new(0),
            hint_ahead: AtomicUsize::new(1),
            width: AtomicUsize::new(0),
            width_shrinks: AtomicU64::new(0),
            width_grows: AtomicU64::new(0),
            steal_boosts: AtomicU64::new(0),
        }
    }

    /// Spans-mode controller reading per-node latency distributions from
    /// `hist`. The cluster passes the process-global bank
    /// ([`crate::obs::hist::global`]); tests pass a private instance.
    pub fn with_spans(nodes: usize, hist: Arc<Hist>) -> Autotune {
        let mut at = Autotune::new(nodes);
        at.spans = Some(Spans { hist, last: Mutex::new(vec![SpansLast::default(); nodes]) });
        at
    }

    /// The input this controller reads, for reports.
    pub fn mode(&self) -> &'static str {
        if self.spans.is_some() { "spans" } else { "on" }
    }

    /// One adaptation round. Called between collectives; cheap (a few
    /// atomic loads per node, or one histogram snapshot per node in
    /// spans mode) so per-collective overhead is noise.
    pub fn adapt(&self, disks: &[Arc<NodeDisk>], pool: &WorkerPool) {
        self.rounds.fetch_add(1, Ordering::Relaxed);
        let moves0 = self.depth_raises.load(Ordering::Relaxed)
            + self.depth_decays.load(Ordering::Relaxed);
        match &self.spans {
            Some(s) => self.adapt_spans(s, disks, pool),
            None => self.adapt_counters(disks, pool),
        }
        // Flight recorder: one instant per adapt round with the decision
        // taken (depth moves this round, hint distance applied).
        let moves = self.depth_raises.load(Ordering::Relaxed)
            + self.depth_decays.load(Ordering::Relaxed)
            - moves0;
        crate::obs::trace::instant(
            crate::obs::trace::Kind::Autotune,
            "autotune.adapt",
            None,
            moves,
            pool.hint_ahead() as u64,
        );
    }

    /// Counter mode: stall-sum deltas drive depth, queue-depth peaks
    /// drive the hint distance.
    fn adapt_counters(&self, disks: &[Arc<NodeDisk>], pool: &WorkerPool) {
        let mut last = self.last_wait.lock().expect("autotune state poisoned");
        for (n, disk) in disks.iter().enumerate() {
            if disk.pipeline_depth() == 0 {
                continue; // synchronous I/O: nothing to tune
            }
            let s = disk.pipe_stats().snapshot();
            let wait = s.reader_wait_ns + s.writer_wait_ns;
            let delta = wait.saturating_sub(last[n]);
            last[n] = wait;
            let cur = disk.effective_depth();
            if delta >= RAISE_STALL_NS {
                // set_effective_depth clamps at the configured ceiling;
                // only count rounds that actually moved the knob
                disk.set_effective_depth(cur + 1);
                if disk.effective_depth() > cur {
                    self.depth_raises.fetch_add(1, Ordering::Relaxed);
                }
            } else if delta <= DECAY_STALL_NS && cur > 1 {
                disk.set_effective_depth(cur - 1);
                self.depth_decays.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Hint distance follows the deepest node queue seen so far: with
        // q tasks waiting behind every dequeue there is real lookahead to
        // warm; with queues ≤ 1 deep wider hints are pure waste.
        let peak = pool
            .stats()
            .per_node_queue_depth()
            .into_iter()
            .max()
            .unwrap_or(0);
        let k = match peak {
            0..=1 => 1,
            2..=3 => 2,
            4..=7 => 3,
            _ => MAX_HINT_AHEAD,
        };
        pool.set_hint_ahead(k);
        self.hint_ahead.store(pool.hint_ahead(), Ordering::Relaxed);
        // Counter mode has no per-node task distributions to read skew
        // from, so the width policy is spans-only; report the width in
        // force without driving it.
        self.width.store(pool.effective_width(), Ordering::Relaxed);
    }

    /// Spans mode: per-node stall-duration p95s (this round's histogram
    /// delta) drive depth; the skew of per-node task p95s drives the
    /// hint distance.
    fn adapt_spans(&self, s: &Spans, disks: &[Arc<NodeDisk>], pool: &WorkerPool) {
        let mut last = s.last.lock().expect("autotune spans state poisoned");
        for (n, disk) in disks.iter().enumerate() {
            let mut cur_stall = s.hist.snapshot(Domain::ReaderStall, n);
            cur_stall.merge(&s.hist.snapshot(Domain::WriterStall, n));
            let delta = cur_stall.delta(&last[n].stall);
            last[n].stall = cur_stall;
            if disk.pipeline_depth() == 0 {
                continue; // synchronous I/O: nothing to tune
            }
            let cur = disk.effective_depth();
            if delta.count() > 0 && delta.p95() >= SPANS_RAISE_P95_NS {
                disk.set_effective_depth(cur + 1);
                if disk.effective_depth() > cur {
                    self.depth_raises.fetch_add(1, Ordering::Relaxed);
                }
            } else if (delta.count() == 0 || delta.p95() <= SPANS_DECAY_P95_NS) && cur > 1 {
                disk.set_effective_depth(cur - 1);
                self.depth_decays.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Hint distance from task-duration skew: when one node's p95
        // task is many times the median node's, its home worker is the
        // straggler everyone waits on — deeper hints keep the stolen /
        // following tasks' chunks warm. Balanced nodes keep the seed's
        // next-task-only hint.
        let mut p95s: Vec<u64> = Vec::with_capacity(last.len());
        for (n, l) in last.iter_mut().enumerate() {
            let cur_task = s.hist.snapshot(Domain::Task, n);
            let delta = cur_task.delta(&l.task);
            l.task = cur_task;
            if delta.count() > 0 {
                p95s.push(delta.p95());
            }
        }
        // `active` = nodes that ran tasks this round; `ratio` = straggler
        // p95 over the median active node's p95 (1 = balanced).
        let active = p95s.len();
        let ratio = if active < 2 {
            1
        } else {
            p95s.sort_unstable();
            // Lower median: with the upper median, two active nodes
            // would divide the max by itself and skew could never be
            // detected.
            let med = p95s[(active - 1) / 2].max(1);
            p95s[active - 1] / med
        };
        let k = match ratio {
            0..=1 => 1,
            2..=3 => 2,
            4..=7 => 3,
            _ => MAX_HINT_AHEAD,
        };
        pool.set_hint_ahead(k);
        self.hint_ahead.store(pool.hint_ahead(), Ordering::Relaxed);

        // Width policy: when fewer nodes than workers had any work *and*
        // the skew is severe, the surplus slots can't drain the straggler
        // (its queue is FIFO-owned by one home worker) — they only churn
        // steal attempts. Narrow the pool to the active-node count;
        // balanced or fully-active rounds grow back to the full pool.
        // Width only changes how many threads a collective spawns, never
        // task order or replay order, so every trajectory is
        // byte-identical (pinned by `det_kernels_are_byte_transparent`).
        let workers = pool.num_workers();
        let prev = pool.effective_width();
        let target = if active > 0 && active < workers && ratio >= 4 {
            active
        } else {
            workers
        };
        pool.set_effective_width(target);
        let now = pool.effective_width();
        if now < prev {
            self.width_shrinks.fetch_add(1, Ordering::Relaxed);
        } else if now > prev {
            self.width_grows.fetch_add(1, Ordering::Relaxed);
        }
        self.width.store(now, Ordering::Relaxed);

        // Steal aggressiveness: under extreme skew the straggler's queue
        // is worth draining from any slot — escalate Bounded→Greedy until
        // the skew clears. (`Off` is never escalated; the pool enforces
        // that.)
        let boost = ratio >= 8;
        if boost && !pool.steal_boost() {
            self.steal_boosts.fetch_add(1, Ordering::Relaxed);
        }
        pool.set_steal_boost(boost);
    }

    /// Adaptation rounds run so far.
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    /// Rounds that raised some node's effective depth.
    pub fn depth_raises(&self) -> u64 {
        self.depth_raises.load(Ordering::Relaxed)
    }

    /// Rounds that decayed some node's effective depth toward 1.
    pub fn depth_decays(&self) -> u64 {
        self.depth_decays.load(Ordering::Relaxed)
    }

    /// Hint distance the controller last applied.
    pub fn hint_ahead(&self) -> usize {
        self.hint_ahead.load(Ordering::Relaxed)
    }

    /// Effective pool width last applied (0 before the first round).
    pub fn width(&self) -> usize {
        self.width.load(Ordering::Relaxed)
    }

    /// Rounds that narrowed the effective width (spans mode only).
    pub fn width_shrinks(&self) -> u64 {
        self.width_shrinks.load(Ordering::Relaxed)
    }

    /// Rounds that grew the effective width back toward the full pool.
    pub fn width_grows(&self) -> u64 {
        self.width_grows.load(Ordering::Relaxed)
    }

    /// Rounds that newly requested the Bounded→Greedy steal escalation.
    pub fn steal_boosts(&self) -> u64 {
        self.steal_boosts.load(Ordering::Relaxed)
    }

    /// One human-readable summary line for [`crate::Roomy::report`].
    pub fn report(&self, disks: &[Arc<NodeDisk>]) -> String {
        let depths: Vec<String> = disks
            .iter()
            .map(|d| d.effective_depth().to_string())
            .collect();
        format!(
            "autotune[{}]: {} rounds, depth +{}/-{}, effective depths [{}], hint ahead {}, \
             width {} (+{}/-{}), steal boosts {}",
            self.mode(),
            self.rounds(),
            self.depth_raises(),
            self.depth_decays(),
            depths.join(" "),
            self.hint_ahead(),
            self.width(),
            self.width_grows(),
            self.width_shrinks(),
            self.steal_boosts(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DiskPolicy;
    use crate::testutil::tmpdir;

    fn disk(depth: usize, dir: &std::path::Path) -> Arc<NodeDisk> {
        Arc::new(
            NodeDisk::create_with_depth(0, dir.join("n0"), DiskPolicy::default(), depth)
                .unwrap(),
        )
    }

    /// A quiet pipeline decays toward depth 1; a stalling one climbs back
    /// to the configured ceiling and never beyond it.
    #[test]
    fn depth_follows_stall_counters() {
        let t = tmpdir("autotune_depth");
        let d = disk(4, t.path());
        let pool = WorkerPool::new(2);
        let at = Autotune::new(1);

        // no stalls recorded → decay one step per round, floor at 1
        for _ in 0..6 {
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 1);
        assert!(at.depth_decays() >= 3);

        // heavy stalls each round → climb to the ceiling, then hold
        for _ in 0..6 {
            d.pipe_stats().add_reader_wait(std::time::Duration::from_millis(5));
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 4, "must stop at io_pipeline_depth");
        assert_eq!(at.depth_raises(), 3);
        assert_eq!(at.rounds(), 12);
    }

    /// Depth-0 (synchronous) disks are never touched.
    #[test]
    fn sync_disks_are_left_alone() {
        let t = tmpdir("autotune_sync");
        let d = disk(0, t.path());
        let pool = WorkerPool::new(1);
        let at = Autotune::new(1);
        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(d.effective_depth(), 0);
        assert_eq!(at.depth_raises() + at.depth_decays(), 0);
    }

    /// Hint distance tracks the peak per-node queue depth.
    #[test]
    fn hint_distance_tracks_queue_depth() {
        let t = tmpdir("autotune_hint");
        let d = disk(2, t.path());
        let pool = WorkerPool::new(2);
        let at = Autotune::new(1);

        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(pool.hint_ahead(), 1, "no queues seen yet");

        pool.stats().note_queue_depths(&[2, 6]);
        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(pool.hint_ahead(), 3);
        assert_eq!(at.hint_ahead(), 3);

        pool.stats().note_queue_depths(&[20, 1]);
        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(pool.hint_ahead(), MAX_HINT_AHEAD);
        assert!(at.report(std::slice::from_ref(&d)).contains("hint ahead"));
        assert!(at.report(std::slice::from_ref(&d)).contains("autotune[on]"));
    }

    /// Spans mode: the depth decision follows the stall-duration p95 of
    /// each round's histogram delta — long typical stalls raise, tiny
    /// ones decay, and the counter sums are ignored entirely.
    #[test]
    fn spans_depth_follows_stall_p95() {
        use std::time::Duration;
        let t = tmpdir("autotune_spans_depth");
        let d = disk(4, t.path());
        let pool = WorkerPool::new(2);
        let hist = Arc::new(Hist::new());
        let at = Autotune::with_spans(1, Arc::clone(&hist));
        assert_eq!(at.mode(), "spans");

        // Quiet bank → decay toward 1 even though nothing was recorded.
        for _ in 0..6 {
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 1);

        // Long typical stalls (p95 ≈ 1 ms ≥ SPANS_RAISE_P95_NS) → climb
        // to the ceiling, then hold.
        for _ in 0..6 {
            for _ in 0..20 {
                hist.record(Domain::ReaderStall, 0, Duration::from_millis(1));
            }
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 4, "must stop at io_pipeline_depth");

        // Thousands of sub-decay-threshold waits per round: a counter
        // sum would scream "stalled" (20 ms/round), the p95 says queue
        // handoff → decay back down.
        for _ in 0..6 {
            for _ in 0..2000 {
                hist.record(Domain::WriterStall, 0, Duration::from_micros(10));
            }
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 1, "tiny-stall storms must decay");
        assert!(at.report(std::slice::from_ref(&d)).contains("autotune[spans]"));
    }

    /// Spans mode: hint distance follows per-node task-p95 skew, not
    /// queue depth.
    #[test]
    fn spans_hint_follows_task_skew() {
        use std::time::Duration;
        let t = tmpdir("autotune_spans_hint");
        let d0 = disk(2, t.path());
        let pool = WorkerPool::new(2);
        let hist = Arc::new(Hist::new());
        let at = Autotune::with_spans(2, Arc::clone(&hist));

        // Balanced nodes: both p95s ≈ 1 ms → ratio 1 → k = 1.
        for _ in 0..10 {
            hist.record(Domain::Task, 0, Duration::from_millis(1));
            hist.record(Domain::Task, 1, Duration::from_millis(1));
        }
        at.adapt(std::slice::from_ref(&d0), &pool);
        assert_eq!(pool.hint_ahead(), 1, "balanced tasks keep the seed hint");

        // Node 1 becomes a straggler: its p95 ≈ 8× node 0's → deep hints.
        for _ in 0..10 {
            hist.record(Domain::Task, 0, Duration::from_millis(1));
            hist.record(Domain::Task, 1, Duration::from_millis(20));
        }
        at.adapt(std::slice::from_ref(&d0), &pool);
        assert!(
            pool.hint_ahead() >= 3,
            "skewed task p95s must widen the hint distance (got {})",
            pool.hint_ahead()
        );

        // One node goes idle (no new tasks): fewer than two live nodes →
        // fall back to the seed hint.
        for _ in 0..10 {
            hist.record(Domain::Task, 0, Duration::from_millis(1));
        }
        at.adapt(std::slice::from_ref(&d0), &pool);
        assert_eq!(pool.hint_ahead(), 1);
    }

    /// Spans mode: the width policy narrows the pool when fewer nodes
    /// than workers are active under severe skew, escalates stealing
    /// under extreme skew, and grows back when the load rebalances.
    #[test]
    fn spans_width_follows_active_nodes_and_skew() {
        use std::time::Duration;
        let t = tmpdir("autotune_spans_width");
        let d0 = disk(2, t.path());
        let pool = WorkerPool::new(4);
        let hist = Arc::new(Hist::new());
        let at = Autotune::with_spans(4, Arc::clone(&hist));
        assert_eq!(at.width(), 0, "no round yet");

        // All four nodes active and balanced → full width, no boost.
        for n in 0..4 {
            for _ in 0..10 {
                hist.record(Domain::Task, n, Duration::from_millis(1));
            }
        }
        at.adapt(std::slice::from_ref(&d0), &pool);
        assert_eq!(pool.effective_width(), 4);
        assert_eq!(at.width(), 4);
        assert!(!pool.steal_boost());
        assert_eq!(at.width_shrinks(), 0);

        // Only two nodes active, one a 20× straggler → narrow to the
        // active count and escalate stealing.
        for _ in 0..10 {
            hist.record(Domain::Task, 0, Duration::from_millis(1));
            hist.record(Domain::Task, 1, Duration::from_millis(20));
        }
        at.adapt(std::slice::from_ref(&d0), &pool);
        assert_eq!(pool.effective_width(), 2, "narrow to the active nodes");
        assert_eq!(at.width(), 2);
        assert_eq!(at.width_shrinks(), 1);
        assert!(pool.steal_boost(), "20× skew must escalate stealing");
        assert_eq!(at.steal_boosts(), 1);

        // Load rebalances across all nodes → grow back, boost clears.
        for n in 0..4 {
            for _ in 0..10 {
                hist.record(Domain::Task, n, Duration::from_millis(1));
            }
        }
        at.adapt(std::slice::from_ref(&d0), &pool);
        assert_eq!(pool.effective_width(), 4);
        assert_eq!(at.width_grows(), 1);
        assert!(!pool.steal_boost());
        let rep = at.report(std::slice::from_ref(&d0));
        assert!(rep.contains("width 4 (+1/-1), steal boosts 1"), "report: {rep}");
    }
}
