//! Counter-driven self-tuning of the I/O pipeline and prefetch distance.
//!
//! Roomy's streaming machinery has two knobs whose best setting depends
//! on the workload, not the configuration: how many chunk buffers each
//! pipelined stream circulates ([`NodeDisk::effective_depth`], seeded
//! from `io_pipeline_depth`), and how far ahead the pool's cross-task
//! prefetch hints reach ([`WorkerPool::hint_ahead`]). The [`Autotune`]
//! controller closes the loop from the metrics the runtime already
//! keeps:
//!
//! - **Pipeline depth** — per node, the growth of
//!   `reader_wait_ns + writer_wait_ns` (time collectives spent blocked
//!   on the I/O lanes, from [`crate::metrics::PipelineStats`]) since the
//!   last round. A stalling node gets one more buffer (up to the
//!   configured `io_pipeline_depth` ceiling — the controller never
//!   exceeds the RAM budget the user chose); a node whose streams never
//!   wait gives buffers back, decaying toward 1.
//! - **Hint distance** — the peak per-node task-queue depth from
//!   [`crate::metrics::PoolStats`]. Deep queues mean each dequeue can
//!   profitably warm several successors; shallow queues keep the seed's
//!   next-task-only hint.
//!
//! One `adapt` round runs **between** collectives (the cluster calls it
//! at the top of each bucket fan-out), never inside one, so a running
//! stream always keeps the depth it started with. Both knobs move *when
//! bytes move*, never *which bytes* — on-disk state stays byte-identical
//! to a run with the controller off, which the determinism suite pins.
//!
//! The controller exists only when
//! [`RoomyConfig::autotune`](crate::RoomyConfig::autotune) is `On`
//! (`ROOMY_AUTOTUNE=on`); in the default `Off` mode the cluster holds no
//! controller and the hot path is exactly the seed's.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::pool::{WorkerPool, MAX_HINT_AHEAD};
use crate::storage::NodeDisk;

/// Pipeline stall growth per round above which a node earns one more
/// chunk buffer: 2 ms of blocked reader/writer time since the last
/// round, i.e. the collective measurably out-ran the I/O lanes.
const RAISE_STALL_NS: u64 = 2_000_000;

/// Stall growth per round below which a node gives one buffer back:
/// under 0.1 ms of waiting means the pipeline is already ahead of the
/// compute and the extra chunk RAM buys nothing.
const DECAY_STALL_NS: u64 = 100_000;

/// Feedback controller adapting per-node pipeline depth and the pool's
/// prefetch-hint distance from runtime counters. One per
/// [`crate::cluster::Cluster`], present only with autotune `On`.
#[derive(Debug)]
pub struct Autotune {
    /// Per-node `reader_wait_ns + writer_wait_ns` at the previous round.
    /// Counters only grow (a metrics reset makes one delta read low —
    /// `saturating_sub` keeps that safe), so deltas are per-round stall.
    last_wait: Mutex<Vec<u64>>,
    rounds: AtomicU64,
    depth_raises: AtomicU64,
    depth_decays: AtomicU64,
    /// Last hint distance applied (for reporting).
    hint_ahead: AtomicUsize,
}

impl Autotune {
    /// Controller for a cluster of `nodes` node disks.
    pub fn new(nodes: usize) -> Autotune {
        Autotune {
            last_wait: Mutex::new(vec![0; nodes]),
            rounds: AtomicU64::new(0),
            depth_raises: AtomicU64::new(0),
            depth_decays: AtomicU64::new(0),
            hint_ahead: AtomicUsize::new(1),
        }
    }

    /// One adaptation round. Called between collectives; cheap (a few
    /// atomic loads per node) so per-collective overhead is noise.
    pub fn adapt(&self, disks: &[Arc<NodeDisk>], pool: &WorkerPool) {
        self.rounds.fetch_add(1, Ordering::Relaxed);
        let moves0 = self.depth_raises.load(Ordering::Relaxed)
            + self.depth_decays.load(Ordering::Relaxed);
        let mut last = self.last_wait.lock().expect("autotune state poisoned");
        for (n, disk) in disks.iter().enumerate() {
            if disk.pipeline_depth() == 0 {
                continue; // synchronous I/O: nothing to tune
            }
            let s = disk.pipe_stats().snapshot();
            let wait = s.reader_wait_ns + s.writer_wait_ns;
            let delta = wait.saturating_sub(last[n]);
            last[n] = wait;
            let cur = disk.effective_depth();
            if delta >= RAISE_STALL_NS {
                // set_effective_depth clamps at the configured ceiling;
                // only count rounds that actually moved the knob
                disk.set_effective_depth(cur + 1);
                if disk.effective_depth() > cur {
                    self.depth_raises.fetch_add(1, Ordering::Relaxed);
                }
            } else if delta <= DECAY_STALL_NS && cur > 1 {
                disk.set_effective_depth(cur - 1);
                self.depth_decays.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Hint distance follows the deepest node queue seen so far: with
        // q tasks waiting behind every dequeue there is real lookahead to
        // warm; with queues ≤ 1 deep wider hints are pure waste.
        let peak = pool
            .stats()
            .per_node_queue_depth()
            .into_iter()
            .max()
            .unwrap_or(0);
        let k = match peak {
            0..=1 => 1,
            2..=3 => 2,
            4..=7 => 3,
            _ => MAX_HINT_AHEAD,
        };
        pool.set_hint_ahead(k);
        self.hint_ahead.store(pool.hint_ahead(), Ordering::Relaxed);
        // Flight recorder: one instant per adapt round with the decision
        // taken (depth moves this round, hint distance applied).
        let moves = self.depth_raises.load(Ordering::Relaxed)
            + self.depth_decays.load(Ordering::Relaxed)
            - moves0;
        crate::obs::trace::instant(
            crate::obs::trace::Kind::Autotune,
            "autotune.adapt",
            None,
            moves,
            pool.hint_ahead() as u64,
        );
    }

    /// Adaptation rounds run so far.
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    /// Rounds that raised some node's effective depth.
    pub fn depth_raises(&self) -> u64 {
        self.depth_raises.load(Ordering::Relaxed)
    }

    /// Rounds that decayed some node's effective depth toward 1.
    pub fn depth_decays(&self) -> u64 {
        self.depth_decays.load(Ordering::Relaxed)
    }

    /// Hint distance the controller last applied.
    pub fn hint_ahead(&self) -> usize {
        self.hint_ahead.load(Ordering::Relaxed)
    }

    /// One human-readable summary line for [`crate::Roomy::report`].
    pub fn report(&self, disks: &[Arc<NodeDisk>]) -> String {
        let depths: Vec<String> = disks
            .iter()
            .map(|d| d.effective_depth().to_string())
            .collect();
        format!(
            "autotune: {} rounds, depth +{}/-{}, effective depths [{}], hint ahead {}",
            self.rounds(),
            self.depth_raises(),
            self.depth_decays(),
            depths.join(" "),
            self.hint_ahead(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DiskPolicy;
    use crate::testutil::tmpdir;

    fn disk(depth: usize, dir: &std::path::Path) -> Arc<NodeDisk> {
        Arc::new(
            NodeDisk::create_with_depth(0, dir.join("n0"), DiskPolicy::default(), depth)
                .unwrap(),
        )
    }

    /// A quiet pipeline decays toward depth 1; a stalling one climbs back
    /// to the configured ceiling and never beyond it.
    #[test]
    fn depth_follows_stall_counters() {
        let t = tmpdir("autotune_depth");
        let d = disk(4, t.path());
        let pool = WorkerPool::new(2);
        let at = Autotune::new(1);

        // no stalls recorded → decay one step per round, floor at 1
        for _ in 0..6 {
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 1);
        assert!(at.depth_decays() >= 3);

        // heavy stalls each round → climb to the ceiling, then hold
        for _ in 0..6 {
            d.pipe_stats().add_reader_wait(std::time::Duration::from_millis(5));
            at.adapt(std::slice::from_ref(&d), &pool);
        }
        assert_eq!(d.effective_depth(), 4, "must stop at io_pipeline_depth");
        assert_eq!(at.depth_raises(), 3);
        assert_eq!(at.rounds(), 12);
    }

    /// Depth-0 (synchronous) disks are never touched.
    #[test]
    fn sync_disks_are_left_alone() {
        let t = tmpdir("autotune_sync");
        let d = disk(0, t.path());
        let pool = WorkerPool::new(1);
        let at = Autotune::new(1);
        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(d.effective_depth(), 0);
        assert_eq!(at.depth_raises() + at.depth_decays(), 0);
    }

    /// Hint distance tracks the peak per-node queue depth.
    #[test]
    fn hint_distance_tracks_queue_depth() {
        let t = tmpdir("autotune_hint");
        let d = disk(2, t.path());
        let pool = WorkerPool::new(2);
        let at = Autotune::new(1);

        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(pool.hint_ahead(), 1, "no queues seen yet");

        pool.stats().note_queue_depths(&[2, 6]);
        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(pool.hint_ahead(), 3);
        assert_eq!(at.hint_ahead(), 3);

        pool.stats().note_queue_depths(&[20, 1]);
        at.adapt(std::slice::from_ref(&d), &pool);
        assert_eq!(pool.hint_ahead(), MAX_HINT_AHEAD);
        assert!(at.report(std::slice::from_ref(&d)).contains("hint ahead"));
    }
}
