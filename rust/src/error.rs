//! Error type shared across the Roomy crate.

use std::path::PathBuf;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, RoomyError>;

/// Errors produced by the Roomy runtime.
#[derive(Debug, thiserror::Error)]
pub enum RoomyError {
    /// Underlying I/O failure, annotated with the path involved.
    #[error("io error on {path:?}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },

    /// Caller passed an argument violating a documented invariant.
    #[error("invalid argument: {0}")]
    InvalidArg(String),

    /// Two structures were combined that do not share a compatible layout
    /// (element size, bucket count, ...).
    #[error("incompatible structures: {0}")]
    Incompatible(String),

    /// A user function id was used that was never registered.
    #[error("unknown function id {id} on structure {structure}")]
    UnknownFunc { structure: String, id: u8 },

    /// XLA/PJRT runtime failure (artifact loading, compilation, execution).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Requested AOT artifact is not present in the artifacts directory.
    #[error("missing artifact {name} (run `make artifacts`)")]
    MissingArtifact { name: String },

    /// A worker thread panicked during a collective operation.
    #[error("worker {worker} panicked during {phase}")]
    WorkerPanic { worker: usize, phase: String },
}

impl RoomyError {
    /// Annotate an `io::Error` with the path it occurred on.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        RoomyError::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for RoomyError {
    fn from(e: xla::Error) -> Self {
        RoomyError::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_error_formats_path() {
        let e = RoomyError::io(
            "/some/file",
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope"),
        );
        let s = e.to_string();
        assert!(s.contains("/some/file"), "{s}");
        assert!(s.contains("nope"), "{s}");
    }

    #[test]
    fn unknown_func_mentions_structure() {
        let e = RoomyError::UnknownFunc { structure: "ra".into(), id: 3 };
        assert!(e.to_string().contains("ra"));
        assert!(e.to_string().contains('3'));
    }
}
