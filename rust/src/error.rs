//! Error type shared across the Roomy crate.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate builds
//! fully offline with zero dependencies.

use std::path::PathBuf;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, RoomyError>;

/// Errors produced by the Roomy runtime.
#[derive(Debug)]
pub enum RoomyError {
    /// Underlying I/O failure, annotated with the path involved.
    Io {
        path: PathBuf,
        source: std::io::Error,
    },

    /// Caller passed an argument violating a documented invariant.
    InvalidArg(String),

    /// Two structures were combined that do not share a compatible layout
    /// (element size, bucket count, ...).
    Incompatible(String),

    /// A user function id was used that was never registered.
    UnknownFunc { structure: String, id: u8 },

    /// XLA/PJRT runtime failure (artifact loading, compilation, execution).
    Xla(String),

    /// Requested AOT artifact is not present in the artifacts directory.
    MissingArtifact { name: String },

    /// A worker thread panicked during a collective operation.
    WorkerPanic { worker: usize, phase: String },

    /// The overlapped-I/O pipeline failed outside an ordinary file
    /// operation (service thread gone, stalled drain, stream poisoned by
    /// an earlier error whose value was already consumed).
    Pipeline(String),

    /// Durable-checkpoint failure ([`crate::storage::checkpoint`]): a
    /// corrupt or missing manifest, a bucket file whose digest no longer
    /// matches the manifest, a geometry mismatch between the checkpoint
    /// and the restoring cluster, or an attempt to snapshot a structure
    /// with pending delayed ops.
    Checkpoint(String),
}

impl std::fmt::Display for RoomyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RoomyError::Io { path, source } => {
                write!(f, "io error on {path:?}: {source}")
            }
            RoomyError::InvalidArg(msg) => write!(f, "invalid argument: {msg}"),
            RoomyError::Incompatible(msg) => write!(f, "incompatible structures: {msg}"),
            RoomyError::UnknownFunc { structure, id } => {
                write!(f, "unknown function id {id} on structure {structure}")
            }
            RoomyError::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            RoomyError::MissingArtifact { name } => {
                write!(f, "missing artifact {name} (run `make artifacts`)")
            }
            RoomyError::WorkerPanic { worker, phase } => {
                write!(f, "worker {worker} panicked during {phase}")
            }
            RoomyError::Pipeline(msg) => write!(f, "io pipeline error: {msg}"),
            RoomyError::Checkpoint(msg) => write!(f, "checkpoint error: {msg}"),
        }
    }
}

impl std::error::Error for RoomyError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RoomyError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl RoomyError {
    /// Annotate an `io::Error` with the path it occurred on.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        RoomyError::Io { path: path.into(), source }
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for RoomyError {
    fn from(e: xla::Error) -> Self {
        RoomyError::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_error_formats_path() {
        let e = RoomyError::io(
            "/some/file",
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope"),
        );
        let s = e.to_string();
        assert!(s.contains("/some/file"), "{s}");
        assert!(s.contains("nope"), "{s}");
    }

    #[test]
    fn unknown_func_mentions_structure() {
        let e = RoomyError::UnknownFunc { structure: "ra".into(), id: 3 };
        assert!(e.to_string().contains("ra"));
        assert!(e.to_string().contains('3'));
    }

    #[test]
    fn checkpoint_error_formats() {
        let e = RoomyError::Checkpoint("digest mismatch in b3.dat".into());
        let s = e.to_string();
        assert!(s.contains("checkpoint"), "{s}");
        assert!(s.contains("b3.dat"), "{s}");
    }

    #[test]
    fn io_error_exposes_source() {
        use std::error::Error;
        let e = RoomyError::io(
            "/f",
            std::io::Error::new(std::io::ErrorKind::Other, "inner"),
        );
        assert!(e.source().is_some());
        assert!(RoomyError::InvalidArg("x".into()).source().is_none());
    }
}
