//! Runtime configuration for a Roomy instance.
//!
//! Roomy's knobs follow the paper's model: a cluster of `workers` nodes,
//! each contributing its local disk; every data structure is split into
//! `workers * buckets_per_worker` buckets, where one bucket is the unit
//! that must fit in RAM during a `sync` (paper §2: buckets are how Arrays
//! and HashTables avoid the external sorts that dominate RoomyList work).

use std::path::PathBuf;

/// Simulated disk performance model, used by the bandwidth/latency
/// experiments (E1/E2) to reproduce the paper's 2010-era disk regime
/// (~100 MB/s streaming, ~5 ms seek) on modern hardware.
///
/// `None` bandwidths disable throttling (full host speed). The throttle is
/// applied in [`crate::storage::diskio`] at the metered read/write calls;
/// seek penalties are charged per file open and per reposition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskPolicy {
    /// Streaming read bandwidth cap, bytes/second.
    pub read_bps: Option<u64>,
    /// Streaming write bandwidth cap, bytes/second.
    pub write_bps: Option<u64>,
    /// Seek penalty charged on every file open / reposition, microseconds.
    pub seek_us: u64,
}

impl DiskPolicy {
    /// No throttling: run at host disk/page-cache speed (the default).
    pub const fn unthrottled() -> Self {
        DiskPolicy { read_bps: None, write_bps: None, seek_us: 0 }
    }

    /// The paper's commodity-disk regime: 100 MB/s streaming, 5 ms seek.
    pub const fn paper_2010() -> Self {
        DiskPolicy {
            read_bps: Some(100 * 1000 * 1000),
            write_bps: Some(100 * 1000 * 1000),
            seek_us: 5_000,
        }
    }

    /// True if any throttling is enabled.
    pub fn is_throttled(&self) -> bool {
        self.read_bps.is_some() || self.write_bps.is_some() || self.seek_us > 0
    }
}

impl Default for DiskPolicy {
    fn default() -> Self {
        Self::unthrottled()
    }
}

/// How the collective pool schedules bucket tasks across its worker
/// slots ([`crate::runtime::pool`]). Worker slots are bound to home nodes
/// (node `n` is homed by slot `n % num_workers`); the policy only governs
/// what an **idle** worker does once its home queues drain. Scheduling
/// moves *where/when* a task runs, never its output: results merge by
/// bucket index and delayed ops replay in (task, issue) order, so every
/// policy yields byte-identical on-disk state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StealPolicy {
    /// Strict locality: a worker only ever runs tasks of its home nodes.
    /// A node with a heavy bucket serializes behind its home worker, but
    /// no worker ever touches another node's data — the multi-node
    /// sharding contract.
    Off,
    /// Home queues first; when idle, steal **one task at a time** from
    /// the LIFO end of the most-loaded node queue (the home worker keeps
    /// draining its FIFO front undisturbed). The default.
    #[default]
    Bounded,
    /// Ignore homes entirely: every worker takes the globally
    /// lowest-index remaining task — the pre-locality flat-cursor
    /// schedule, kept as the bench baseline.
    Greedy,
}

impl StealPolicy {
    /// Parse the `off` / `bounded` / `greedy` spelling used by the env
    /// var and CLI flag.
    pub fn parse(s: &str) -> Option<StealPolicy> {
        Some(match s {
            "off" => StealPolicy::Off,
            "bounded" => StealPolicy::Bounded,
            "greedy" => StealPolicy::Greedy,
            _ => return None,
        })
    }

    /// The canonical spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            StealPolicy::Off => "off",
            StealPolicy::Bounded => "bounded",
            StealPolicy::Greedy => "greedy",
        }
    }
}

impl std::str::FromStr for StealPolicy {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        StealPolicy::parse(s).ok_or_else(|| format!("bad steal policy {s:?} (off|bounded|greedy)"))
    }
}

impl std::fmt::Display for StealPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Counter-driven self-tuning of the I/O pipeline
/// ([`crate::runtime::autotune`]). `Off` (the default) leaves every knob
/// exactly where the config put it — byte-for-byte and timing-knob
/// identical to the seed. `On` lets the controller adapt each node's
/// *effective* pipeline depth (within `1..=io_pipeline_depth`) from
/// pipeline stall counters, and the pool's hint-ahead distance from
/// per-node queue-depth peaks, between collectives. Tuning only moves
/// buffering/prefetch knobs that are already proven byte-invisible, so
/// on-disk state is identical in both modes (`tests/determinism.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AutotuneMode {
    /// No adaptation (seed behavior). The default.
    #[default]
    Off,
    /// Adapt effective pipeline depth + hint-ahead between collectives
    /// from the coarse end-of-collective counters.
    On,
    /// Like `On`, but read the latency *distributions* instead of the
    /// coarse sums: per-node stall p95s from [`crate::obs::hist`] drive
    /// depth, and per-node task-duration p95 skew drives the hint-ahead
    /// distance. Implies arming the histogram bank.
    Spans,
}

impl AutotuneMode {
    /// Parse the `off` / `on` / `spans` spelling used by the env var and
    /// CLI flag.
    pub fn parse(s: &str) -> Option<AutotuneMode> {
        Some(match s {
            "off" => AutotuneMode::Off,
            "on" => AutotuneMode::On,
            "spans" => AutotuneMode::Spans,
            _ => return None,
        })
    }

    /// The canonical spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            AutotuneMode::Off => "off",
            AutotuneMode::On => "on",
            AutotuneMode::Spans => "spans",
        }
    }

    /// True when the controller should run.
    pub fn enabled(&self) -> bool {
        !matches!(self, AutotuneMode::Off)
    }
}

impl std::str::FromStr for AutotuneMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        AutotuneMode::parse(s).ok_or_else(|| format!("bad autotune mode {s:?} (off|on|spans)"))
    }
}

impl std::fmt::Display for AutotuneMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which implementation backs the batched fingerprint / bitset kernels
/// ([`crate::hashfn`], [`crate::roomy::bitkernels`]). Every choice is
/// **bit-exact** — the kernels are pinned to produce fingerprints and
/// bucket bytes identical to the scalar reference loops
/// (`tests/determinism.rs`), so this knob trades speed only, never
/// on-disk layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum KernelMode {
    /// Runtime-detect the widest available lane implementation (AVX2 on
    /// x86_64, otherwise the portable unrolled lanes). The default.
    #[default]
    Auto = 0,
    /// Force the portable 4-lane unrolled kernels (no `std::arch`) — the
    /// path non-x86 targets always take; CI pins it via
    /// `ROOMY_KERNELS=portable`.
    Portable = 1,
    /// Force the per-record scalar reference loops (the pre-batch
    /// behavior) — the A/B baseline for benches and the determinism
    /// kernel matrix.
    Scalar = 2,
}

impl KernelMode {
    /// Parse the `auto` / `portable` / `scalar` spelling used by the
    /// `ROOMY_KERNELS` env var.
    pub fn parse(s: &str) -> Option<KernelMode> {
        Some(match s {
            "auto" => KernelMode::Auto,
            "portable" => KernelMode::Portable,
            "scalar" => KernelMode::Scalar,
            _ => return None,
        })
    }

    /// The canonical spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Portable => "portable",
            KernelMode::Scalar => "scalar",
        }
    }

    /// Inverse of the `repr(u8)` discriminant (for the process-global
    /// atomic in [`crate::hashfn`]); unknown values fall back to `Auto`.
    pub(crate) fn from_u8(v: u8) -> KernelMode {
        match v {
            1 => KernelMode::Portable,
            2 => KernelMode::Scalar,
            _ => KernelMode::Auto,
        }
    }
}

impl std::str::FromStr for KernelMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        KernelMode::parse(s).ok_or_else(|| format!("bad kernel mode {s:?} (auto|portable|scalar)"))
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which implementation backs the numeric batch kernels in [`crate::accel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccelMode {
    /// Pure-Rust fallbacks (always available; bit-exact with the XLA path).
    Rust,
    /// AOT-compiled XLA artifacts from `artifacts/` via PJRT.
    Xla,
    /// Use XLA when the artifacts directory is present, Rust otherwise.
    Auto,
}

/// Configuration for a [`crate::Roomy`] instance.
#[derive(Debug, Clone)]
pub struct RoomyConfig {
    /// Number of simulated cluster nodes (disk directories). Paper: one
    /// process per cluster node. This is a *data layout* knob — it fixes
    /// how many disks data is spread over, not how many threads run.
    pub workers: usize,
    /// Buckets per worker. More buckets = smaller RAM-resident unit per
    /// sync and finer shuffle granularity.
    pub buckets_per_worker: usize,
    /// Worker threads in the collective execution pool
    /// ([`crate::runtime::pool`]). Independent hash buckets are processed
    /// concurrently by this many threads during every collective (sync,
    /// map, reduce, sort, merge); results and delayed-op side effects are
    /// merged deterministically, so any value produces byte-identical
    /// on-disk state. Decoupled from `workers`: layout says *where* bytes
    /// live, `num_workers` says how much CPU streams them.
    pub num_workers: usize,
    /// Root directory under which per-node disk directories are created.
    pub root: PathBuf,
    /// Directory for durable checkpoints ([`crate::storage::checkpoint`]).
    /// `None` (the default) puts them under `<root>/checkpoints/`, which
    /// sits *beside* the per-node disk directories and therefore survives
    /// both the scoped scratch purge at cluster bring-up and any structure
    /// teardown. Keeping the default on the same filesystem as the node
    /// disks lets snapshots hardlink bucket files instead of copying them;
    /// pointing it at another filesystem still works (copy fallback). CLI
    /// `--checkpoint-dir`.
    pub checkpoint_dir: Option<PathBuf>,
    /// Staged delayed-op bytes per bucket before spilling to disk.
    pub op_buffer_bytes: usize,
    /// In-collective op-capture bytes per pool task — one **flat budget
    /// shared across all of the task's destination structures** — before
    /// the largest capture log spills to a scratch file under
    /// `tmp/capture/` on the task's node disk. Keeps capture-heavy
    /// collectives (e.g. BFS frontier expansion) inside the strict space
    /// bound: per-task capture RAM is O(threshold), however many
    /// structures the task stages into. Independent knob whose default
    /// *value* matches `op_buffer_bytes`'s default (changing one does not
    /// move the other); env `ROOMY_CAPTURE_SPILL` overrides, CLI
    /// `--capture-spill`.
    pub capture_spill_threshold: usize,
    /// Chunk buffers per pipelined bucket stream
    /// ([`crate::storage::pipeline`]): 0 keeps every read/write
    /// synchronous (the seed behavior); depth d ≥ 1 runs a per-node I/O
    /// service and lets a pool task compute on one chunk while the
    /// service reads the next ahead and flushes the previous behind.
    /// On-disk bytes are identical at every depth; peak pipeline RAM per
    /// stream is depth × [`crate::storage::PIPE_CHUNK`]. Env
    /// `ROOMY_IO_DEPTH` overrides, CLI `--io-depth`.
    pub io_pipeline_depth: usize,
    /// How idle pool workers acquire tasks from other nodes' queues
    /// ([`crate::runtime::pool`]): `Off` is strict locality, `Bounded`
    /// (default) steals one task at a time from the most-loaded queue,
    /// `Greedy` reproduces the old flat-cursor schedule. Byte-identical
    /// on-disk state at every setting. Env `ROOMY_STEAL` overrides, CLI
    /// `--steal`.
    pub steal_policy: StealPolicy,
    /// Bits per key for the per-node approximate-membership dedup tier
    /// ([`crate::storage::bloom`]): 0 (the default) disables the filter
    /// entirely — the seed behavior. A value `b > 0` gives every
    /// list/set/hashtable bucket a scalable bloom filter sized at `b`
    /// bits per inserted record (`k = round(b·ln 2)` probe hashes); a
    /// record the filter proves **definitely new** skips the exact
    /// sort-merge / full-bucket-replay path and appends directly, while
    /// any "maybe seen" answer falls through to the exact pass — so
    /// on-disk bytes stay identical with the filter on or off
    /// (`tests/determinism.rs` pins this). Filter RAM is metered in
    /// [`crate::metrics::DedupStats`] against the space bound. Env
    /// `ROOMY_BLOOM` overrides, CLI `--bloom`.
    pub bloom_bits_per_key: usize,
    /// Opt-in approximate dedup mode (requires `bloom_bits_per_key > 0`):
    /// treat a bloom "maybe seen" answer as **seen** instead of falling
    /// through to the exact pass. This trades a small, measured
    /// false-positive rate (genuinely-new records wrongly dropped as
    /// duplicates — bounded by the bits-per-key budget and reported in
    /// [`crate::metrics::DedupStats`]) for skipping the exact merge
    /// entirely. Results are no longer byte-identical to exact mode;
    /// BFS level counts become lower bounds. Env `ROOMY_BLOOM_APPROX`
    /// (any non-empty value), CLI `--bloom-approx`.
    pub bloom_approximate: bool,
    /// Counter-driven self-tuning ([`crate::runtime::autotune`]): `Off`
    /// (default) pins every knob to its configured value — the seed
    /// behavior; `On` adapts each node's effective pipeline depth and the
    /// pool's hint-ahead distance from the previous collective's stall /
    /// queue-depth counters. On-disk bytes identical in both modes. Env
    /// `ROOMY_AUTOTUNE` ∈ off|on overrides, CLI `--autotune`.
    pub autotune: AutotuneMode,
    /// Fingerprint / bitset kernel implementation ([`crate::hashfn`]):
    /// `Auto` (default) runtime-detects AVX2 and otherwise runs the
    /// portable unrolled lanes; `Portable` forces the fallback; `Scalar`
    /// forces the per-record reference loops. All bit-exact — on-disk
    /// bytes never depend on this knob (`tests/determinism.rs`). Env
    /// `ROOMY_KERNELS` ∈ auto|portable|scalar overrides.
    pub kernels: KernelMode,
    /// In-RAM run size for external sort (bytes).
    pub sort_chunk_bytes: usize,
    /// RAM budget per worker for hash-set based `remove_all` before
    /// falling back to sort-merge difference (bytes).
    pub ram_budget_bytes: usize,
    /// Simulated disk performance model.
    pub disk: DiskPolicy,
    /// Numeric batch kernel backend.
    pub accel: AccelMode,
    /// Directory holding AOT artifacts (`make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Flight-recorder destination ([`crate::obs::trace`]): `None` (the
    /// default) leaves tracing off — counters only, ~zero cost. A path
    /// arms the process-global span recorder on [`crate::Roomy::open`]
    /// and flushes Chrome-trace-event JSON there on teardown (or via
    /// `Roomy::flush_trace()`). Recording never touches the data paths:
    /// on-disk bytes are identical with tracing on or off
    /// (`tests/determinism.rs` pins this). Env `ROOMY_TRACE=<path>`
    /// overrides, CLI `--trace`.
    pub trace_path: Option<PathBuf>,
    /// Latency histograms ([`crate::obs::hist`]): `false` (the default)
    /// leaves the bank disarmed — each record site costs one relaxed
    /// atomic load and nothing else. `true` arms the process-global
    /// log2-bucket histograms of pool task durations (per node),
    /// pipeline reader/writer stalls, and per-collective wall times;
    /// merged p50/p95/p99 surface in `Roomy::report()` /
    /// `report_json()`. Recording never touches the data paths: on-disk
    /// bytes are identical with histograms on or off
    /// (`tests/determinism.rs` pins this). `autotune = spans` arms the
    /// bank implicitly. Env `ROOMY_HIST` (any non-empty value)
    /// overrides, CLI `--hist`.
    pub hist: bool,
}

impl RoomyConfig {
    /// A small configuration rooted at a fresh temp directory, suitable for
    /// tests and examples.
    pub fn for_testing(root: impl Into<PathBuf>) -> Self {
        RoomyConfig {
            workers: 4,
            buckets_per_worker: 2,
            num_workers: env_num_workers().unwrap_or(2),
            root: root.into(),
            checkpoint_dir: None,
            op_buffer_bytes: 64 * 1024,
            capture_spill_threshold: env_capture_spill().unwrap_or(64 * 1024),
            io_pipeline_depth: env_io_depth().unwrap_or(0),
            steal_policy: env_steal().unwrap_or_default(),
            bloom_bits_per_key: env_bloom().unwrap_or(0),
            bloom_approximate: env_bloom_approx(),
            autotune: env_autotune().unwrap_or_default(),
            kernels: env_kernels().unwrap_or_default(),
            sort_chunk_bytes: 4 * 1024 * 1024,
            ram_budget_bytes: 64 * 1024 * 1024,
            disk: DiskPolicy::unthrottled(),
            accel: AccelMode::Rust,
            artifacts_dir: PathBuf::from("artifacts"),
            trace_path: env_trace(),
            hist: env_hist(),
        }
    }

    /// Total bucket count for every structure created by this instance.
    pub fn nbuckets(&self) -> usize {
        self.workers * self.buckets_per_worker
    }

    /// Validate invariants; called by [`crate::Roomy::open`].
    pub fn validate(&self) -> crate::Result<()> {
        if self.workers == 0 {
            return Err(crate::RoomyError::InvalidArg("workers must be > 0".into()));
        }
        if self.buckets_per_worker == 0 {
            return Err(crate::RoomyError::InvalidArg(
                "buckets_per_worker must be > 0".into(),
            ));
        }
        if self.nbuckets() > u32::MAX as usize {
            return Err(crate::RoomyError::InvalidArg("too many buckets".into()));
        }
        if self.num_workers == 0 {
            return Err(crate::RoomyError::InvalidArg(
                "num_workers must be > 0".into(),
            ));
        }
        if self.bloom_approximate && self.bloom_bits_per_key == 0 {
            return Err(crate::RoomyError::InvalidArg(
                "bloom_approximate requires bloom_bits_per_key > 0".into(),
            ));
        }
        if self.op_buffer_bytes == 0
            || self.sort_chunk_bytes == 0
            || self.capture_spill_threshold == 0
        {
            return Err(crate::RoomyError::InvalidArg(
                "buffer sizes must be > 0".into(),
            ));
        }
        Ok(())
    }
}

/// Pool width override from the environment (`ROOMY_NUM_WORKERS`), used by
/// CI to force a specific parallelism across the whole test suite.
fn env_num_workers() -> Option<usize> {
    std::env::var("ROOMY_NUM_WORKERS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
}

/// Capture-spill threshold override (`ROOMY_CAPTURE_SPILL`, bytes), used
/// by CI to force the in-collective spill path on every test regardless
/// of data volume.
fn env_capture_spill() -> Option<usize> {
    std::env::var("ROOMY_CAPTURE_SPILL")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
}

/// Pipeline-depth override (`ROOMY_IO_DEPTH`, chunk buffers per stream;
/// 0 = synchronous), used by CI to run the whole suite with overlapped
/// bucket I/O.
fn env_io_depth() -> Option<usize> {
    std::env::var("ROOMY_IO_DEPTH")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
}

/// Steal-policy override (`ROOMY_STEAL` ∈ off|bounded|greedy), used by CI
/// to run the whole suite under strict locality.
fn env_steal() -> Option<StealPolicy> {
    std::env::var("ROOMY_STEAL").ok().as_deref().and_then(StealPolicy::parse)
}

/// Bloom bits-per-key override (`ROOMY_BLOOM`; 0 = filter off), used by
/// CI to run the whole suite with the approximate-membership dedup tier
/// fronting every exact pass.
fn env_bloom() -> Option<usize> {
    std::env::var("ROOMY_BLOOM").ok().and_then(|s| s.parse::<usize>().ok())
}

/// Approximate-dedup override (`ROOMY_BLOOM_APPROX`; any non-empty value
/// enables it). Exact-backed mode stays the default everywhere.
fn env_bloom_approx() -> bool {
    std::env::var("ROOMY_BLOOM_APPROX").map(|s| !s.is_empty()).unwrap_or(false)
}

/// Autotune override (`ROOMY_AUTOTUNE` ∈ off|on), used by CI to run the
/// whole suite with the self-tuning controller active.
fn env_autotune() -> Option<AutotuneMode> {
    std::env::var("ROOMY_AUTOTUNE").ok().as_deref().and_then(AutotuneMode::parse)
}

/// Kernel-mode override (`ROOMY_KERNELS` ∈ auto|portable|scalar), used by
/// CI to run the whole suite on the portable fallback lanes.
fn env_kernels() -> Option<KernelMode> {
    std::env::var("ROOMY_KERNELS").ok().as_deref().and_then(KernelMode::parse)
}

/// Flight-recorder override (`ROOMY_TRACE=<path>`; empty = off), used by
/// CI to run the whole suite with span recording armed.
fn env_trace() -> Option<PathBuf> {
    std::env::var("ROOMY_TRACE").ok().filter(|s| !s.is_empty()).map(PathBuf::from)
}

/// Latency-histogram override (`ROOMY_HIST`; any non-empty value arms the
/// bank), used by CI to run the whole suite with histograms recording.
fn env_hist() -> bool {
    std::env::var("ROOMY_HIST").map(|s| !s.is_empty()).unwrap_or(false)
}

impl Default for RoomyConfig {
    fn default() -> Self {
        RoomyConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            buckets_per_worker: 4,
            num_workers: env_num_workers().unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            }),
            root: std::env::temp_dir().join("roomy"),
            checkpoint_dir: None,
            op_buffer_bytes: 4 * 1024 * 1024,
            capture_spill_threshold: env_capture_spill().unwrap_or(4 * 1024 * 1024),
            io_pipeline_depth: env_io_depth().unwrap_or(2),
            steal_policy: env_steal().unwrap_or_default(),
            bloom_bits_per_key: env_bloom().unwrap_or(0),
            bloom_approximate: env_bloom_approx(),
            autotune: env_autotune().unwrap_or_default(),
            kernels: env_kernels().unwrap_or_default(),
            sort_chunk_bytes: 64 * 1024 * 1024,
            ram_budget_bytes: 256 * 1024 * 1024,
            disk: DiskPolicy::unthrottled(),
            accel: AccelMode::Auto,
            artifacts_dir: PathBuf::from("artifacts"),
            trace_path: env_trace(),
            hist: env_hist(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nbuckets_is_product() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        c.workers = 3;
        c.buckets_per_worker = 5;
        assert_eq!(c.nbuckets(), 15);
    }

    #[test]
    fn validation_rejects_zero_workers() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        c.workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_buffers() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        c.op_buffer_bytes = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_capture_threshold() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        c.capture_spill_threshold = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn default_validates() {
        RoomyConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_rejects_zero_pool_workers() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        c.num_workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn any_pipeline_depth_validates() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        for depth in [0usize, 1, 4, 1024] {
            c.io_pipeline_depth = depth;
            c.validate().unwrap();
        }
    }

    #[test]
    fn steal_policy_parses_and_round_trips() {
        for p in [StealPolicy::Off, StealPolicy::Bounded, StealPolicy::Greedy] {
            assert_eq!(StealPolicy::parse(p.as_str()), Some(p));
            assert_eq!(p.as_str().parse::<StealPolicy>().unwrap(), p);
        }
        assert_eq!(StealPolicy::parse("half"), None);
        assert!("".parse::<StealPolicy>().is_err());
        assert_eq!(StealPolicy::default(), StealPolicy::Bounded);
    }

    #[test]
    fn bloom_defaults_off_and_any_width_validates() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        if std::env::var("ROOMY_BLOOM").is_err() {
            assert_eq!(c.bloom_bits_per_key, 0, "filter must default off (seed behavior)");
        }
        for bits in [0usize, 1, 10, 64] {
            c.bloom_bits_per_key = bits;
            c.validate().unwrap();
        }
    }

    #[test]
    fn approximate_mode_requires_a_filter() {
        let mut c = RoomyConfig::for_testing("/tmp/x");
        c.bloom_bits_per_key = 0;
        c.bloom_approximate = true;
        assert!(c.validate().is_err());
        c.bloom_bits_per_key = 10;
        c.validate().unwrap();
    }

    #[test]
    fn autotune_parses_and_defaults_off() {
        for m in [AutotuneMode::Off, AutotuneMode::On, AutotuneMode::Spans] {
            assert_eq!(AutotuneMode::parse(m.as_str()), Some(m));
            assert_eq!(m.as_str().parse::<AutotuneMode>().unwrap(), m);
        }
        assert_eq!(AutotuneMode::parse("auto"), None);
        assert!("".parse::<AutotuneMode>().is_err());
        assert_eq!(AutotuneMode::default(), AutotuneMode::Off);
        assert!(!AutotuneMode::Off.enabled());
        assert!(AutotuneMode::On.enabled());
        assert!(AutotuneMode::Spans.enabled());
        let c = RoomyConfig::for_testing("/tmp/x");
        if std::env::var("ROOMY_AUTOTUNE").is_err() {
            assert_eq!(c.autotune, AutotuneMode::Off, "must default off (seed behavior)");
        }
        c.validate().unwrap();
    }

    #[test]
    fn kernels_parse_and_default_auto() {
        for m in [KernelMode::Auto, KernelMode::Portable, KernelMode::Scalar] {
            assert_eq!(KernelMode::parse(m.as_str()), Some(m));
            assert_eq!(m.as_str().parse::<KernelMode>().unwrap(), m);
            assert_eq!(KernelMode::from_u8(m as u8), m);
        }
        assert_eq!(KernelMode::parse("avx2"), None);
        assert!("".parse::<KernelMode>().is_err());
        assert_eq!(KernelMode::default(), KernelMode::Auto);
        let c = RoomyConfig::for_testing("/tmp/x");
        if std::env::var("ROOMY_KERNELS").is_err() {
            assert_eq!(c.kernels, KernelMode::Auto);
        }
        c.validate().unwrap();
    }

    #[test]
    fn hist_defaults_off() {
        let c = RoomyConfig::for_testing("/tmp/x");
        if std::env::var("ROOMY_HIST").is_err() {
            assert!(!c.hist, "histograms must default off (seed behavior)");
        }
        c.validate().unwrap();
    }

    #[test]
    fn paper_policy_is_throttled() {
        assert!(DiskPolicy::paper_2010().is_throttled());
        assert!(!DiskPolicy::unthrottled().is_throttled());
    }
}
