//! Numeric batch kernels with two interchangeable backends:
//!
//! - **Xla**: the AOT-compiled Pallas/JAX artifacts executed via PJRT
//!   ([`crate::runtime::Engine`]) — the three-layer architecture's fast
//!   path;
//! - **Rust**: bit-exact scalar fallbacks, always available.
//!
//! Both paths produce *identical* bits (pinned by tests and by the shared
//! vectors in [`crate::hashfn`]), so callers may mix them freely; the E7
//! bench ablates one against the other.
//!
//! The kernels cover Roomy's batch hot spots:
//! - [`Accel::hash_partition`] — fingerprint + route a batch of elements;
//! - [`Accel::prefix_scan`] — inclusive scan (parallel-prefix construct);
//! - [`Accel::reduce_sumsq`] — the paper's reduce example;
//! - [`Accel::bfs_expand`] — fused pancake frontier expansion
//!   (neighbors → packed codes → fingerprints → destination buckets).

use std::sync::Arc;

use crate::error::Result;
use crate::hashfn;
use crate::roomy::Roomy;
use crate::runtime::{Engine, TensorBuf, BFS_BATCH, HASH_BATCH, REDUCE_BATCH, SCAN_BATCH};

/// Which backend executes the batch kernels.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust scalar implementations.
    Rust,
    /// AOT XLA artifacts via the PJRT engine.
    Xla(Arc<Engine>),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Rust => write!(f, "Rust"),
            Backend::Xla(_) => write!(f, "Xla"),
        }
    }
}

/// Batch-kernel dispatcher.
#[derive(Debug, Clone)]
pub struct Accel {
    backend: Backend,
}

/// Result of one fused BFS expansion call: parallel vectors over all
/// generated neighbors (`frontier_len * (n-1)` entries).
#[derive(Debug, Default)]
pub struct Expansion {
    /// Nibble-packed neighbor permutations.
    pub packed: Vec<u64>,
    /// Fingerprints of the packed codes.
    pub fp: Vec<u64>,
    /// Destination bucket of each neighbor.
    pub bucket: Vec<u32>,
}

impl Accel {
    /// Always-available Rust backend.
    pub fn rust() -> Accel {
        Accel { backend: Backend::Rust }
    }

    /// XLA backend over a loaded engine.
    pub fn xla(engine: Arc<Engine>) -> Accel {
        Accel { backend: Backend::Xla(engine) }
    }

    /// Backend selected by a [`Roomy`] instance's `AccelMode`.
    pub fn from_roomy(r: &Roomy) -> Accel {
        match r.engine() {
            Some(e) => Accel::xla(e),
            None => Accel::rust(),
        }
    }

    /// True if this dispatcher runs on XLA.
    pub fn is_xla(&self) -> bool {
        matches!(self.backend, Backend::Xla(_))
    }

    // ------------------------------------------------------------------
    // hash_partition
    // ------------------------------------------------------------------

    /// Fingerprint + bucket-route a batch of K-word elements
    /// (`words.len()` must be a multiple of `k`; `k` ∈ {1, 2} on the XLA
    /// path, any `k` on the Rust path).
    pub fn hash_partition(
        &self,
        words: &[u64],
        k: usize,
        nbuckets: u32,
    ) -> Result<(Vec<u64>, Vec<u32>)> {
        assert!(k > 0 && words.len().is_multiple_of(k));
        let n = words.len() / k;
        match &self.backend {
            Backend::Xla(engine) if k <= 2 => {
                let name = if k == 1 { "hash_partition_k1" } else { "hash_partition_k2" };
                let mut fps = Vec::with_capacity(n);
                let mut buckets = Vec::with_capacity(n);
                for chunk in words.chunks(HASH_BATCH * k) {
                    let real = chunk.len() / k;
                    let mut padded = chunk.to_vec();
                    padded.resize(HASH_BATCH * k, 0);
                    let out = engine.run(
                        name,
                        vec![
                            TensorBuf::u64_2d(padded, HASH_BATCH, k),
                            TensorBuf::u64_1d(vec![nbuckets as u64]),
                        ],
                    )?;
                    let mut it = out.into_iter();
                    let fp = it.next().expect("fp output").into_u64()?;
                    let bk = it.next().expect("bucket output").into_u64()?;
                    fps.extend_from_slice(&fp[..real]);
                    buckets.extend(bk[..real].iter().map(|&b| b as u32));
                }
                Ok((fps, buckets))
            }
            _ => {
                // One batched lane sweep over the whole chunk (bit-exact
                // with the per-record scalar loop in every kernel mode).
                let mut fps = Vec::with_capacity(n);
                hashfn::fp_words_batch_into(words, k, &mut fps);
                let buckets = fps.iter().map(|&fp| hashfn::bucket_of(fp, nbuckets)).collect();
                Ok((fps, buckets))
            }
        }
    }

    // ------------------------------------------------------------------
    // prefix_scan
    // ------------------------------------------------------------------

    /// Inclusive prefix sum (wrapping i64). Returns `(scan, total)`.
    pub fn prefix_scan(&self, x: &[i64]) -> Result<(Vec<i64>, i64)> {
        match &self.backend {
            Backend::Xla(engine) => {
                let mut out = Vec::with_capacity(x.len());
                let mut carry = 0i64;
                for chunk in x.chunks(SCAN_BATCH) {
                    let mut padded = chunk.to_vec();
                    padded.resize(SCAN_BATCH, 0);
                    let res = engine.run("prefix_scan", vec![TensorBuf::i64_1d(padded)])?;
                    let mut it = res.into_iter();
                    let scan = it.next().expect("scan output").into_i64()?;
                    // Carry-in from previous batches is propagated here in
                    // L3, exactly as Roomy propagates partial sums across
                    // disk buckets.
                    out.extend(scan[..chunk.len()].iter().map(|v| v.wrapping_add(carry)));
                    carry = *out.last().unwrap_or(&carry);
                }
                Ok((out, carry))
            }
            Backend::Rust => {
                let mut out = Vec::with_capacity(x.len());
                let mut acc = 0i64;
                for &v in x {
                    acc = acc.wrapping_add(v);
                    out.push(acc);
                }
                Ok((out, acc))
            }
        }
    }

    // ------------------------------------------------------------------
    // reduce_sumsq
    // ------------------------------------------------------------------

    /// `(sum of squares, min, max)` over `x` (wrapping i64). Empty input
    /// yields `(0, i64::MAX, i64::MIN)` — the reduce identities.
    pub fn reduce_sumsq(&self, x: &[i64]) -> Result<(i64, i64, i64)> {
        match &self.backend {
            Backend::Xla(engine) => {
                let (mut sumsq, mut mn, mut mx) = (0i64, i64::MAX, i64::MIN);
                for chunk in x.chunks(REDUCE_BATCH) {
                    let mut padded = chunk.to_vec();
                    // Padding zeros contribute 0 to sumsq but would corrupt
                    // min/max; for partial chunks the bounds are folded on
                    // the Rust side instead.
                    padded.resize(REDUCE_BATCH, 0);
                    let res = engine.run("reduce_sumsq", vec![TensorBuf::i64_1d(padded)])?;
                    let vals: Vec<i64> = res
                        .into_iter()
                        .map(|t| t.into_i64().map(|v| v[0]))
                        .collect::<Result<_>>()?;
                    sumsq = sumsq.wrapping_add(vals[0]);
                    if chunk.len() == REDUCE_BATCH {
                        mn = mn.min(vals[1]);
                        mx = mx.max(vals[2]);
                    } else {
                        for &v in chunk {
                            mn = mn.min(v);
                            mx = mx.max(v);
                        }
                    }
                }
                Ok((sumsq, mn, mx))
            }
            Backend::Rust => {
                let mut sumsq = 0i64;
                let (mut mn, mut mx) = (i64::MAX, i64::MIN);
                for &v in x {
                    sumsq = sumsq.wrapping_add(v.wrapping_mul(v));
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
                Ok((sumsq, mn, mx))
            }
        }
    }

    // ------------------------------------------------------------------
    // bfs_expand
    // ------------------------------------------------------------------

    /// Fused pancake frontier expansion: for every nibble-packed
    /// permutation in `frontier` (size `n`, `n` ∈ 2..=16), generate all
    /// `n-1` prefix reversals with packed code, fingerprint and
    /// destination bucket.
    ///
    /// XLA path available for `n` with a lowered `bfs_expand_n{n}`
    /// artifact (6..=12 by default); other sizes fall back to Rust.
    pub fn bfs_expand(&self, frontier: &[u64], n: usize, nbuckets: u32) -> Result<Expansion> {
        assert!((2..=16).contains(&n));
        match &self.backend {
            Backend::Xla(engine) if engine.has(&format!("bfs_expand_n{n}")) => {
                let name = format!("bfs_expand_n{n}");
                let out_per = n - 1;
                let mut exp = Expansion {
                    packed: Vec::with_capacity(frontier.len() * out_per),
                    fp: Vec::with_capacity(frontier.len() * out_per),
                    bucket: Vec::with_capacity(frontier.len() * out_per),
                };
                let identity = crate::apps::pancake::identity_packed(n);
                for chunk in frontier.chunks(BFS_BATCH) {
                    // Packed codes are the wire format; pad with identity.
                    let mut codes = chunk.to_vec();
                    codes.resize(BFS_BATCH, identity);
                    let out = engine.run(
                        &name,
                        vec![
                            TensorBuf::u64_1d(codes),
                            TensorBuf::u64_1d(vec![nbuckets as u64]),
                        ],
                    )?;
                    // outputs: packed u64[B,n-1], fp u64[B,n-1],
                    // bucket u64[B,n-1]
                    let mut it = out.into_iter();
                    let packed = it.next().expect("packed").into_u64()?;
                    let fp = it.next().expect("fp").into_u64()?;
                    let bucket = it.next().expect("bucket").into_u64()?;
                    let real = chunk.len() * out_per;
                    exp.packed.extend_from_slice(&packed[..real]);
                    exp.fp.extend_from_slice(&fp[..real]);
                    exp.bucket.extend(bucket[..real].iter().map(|&b| b as u32));
                }
                Ok(exp)
            }
            _ => {
                let out_per = n - 1;
                let total = frontier.len() * out_per;
                let mut exp = Expansion {
                    packed: Vec::with_capacity(total),
                    fp: Vec::with_capacity(total),
                    bucket: Vec::with_capacity(total),
                };
                // Generate all neighbor codes first, then fingerprint the
                // whole expansion in one batched sweep.
                for &code in frontier {
                    for k in 2..=n {
                        exp.packed.push(crate::apps::pancake::flip_packed(code, k as u32));
                    }
                }
                hashfn::fp_words_batch_into(&exp.packed, 1, &mut exp.fp);
                exp.bucket.extend(exp.fp.iter().map(|&fp| hashfn::bucket_of(fp, nbuckets)));
                Ok(exp)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::pancake;

    fn xla_accel() -> Option<Accel> {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            // load fails on non-`xla` builds even with artifacts present
            Engine::load(dir).ok().map(|e| Accel::xla(Arc::new(e)))
        } else {
            None
        }
    }

    #[test]
    fn rust_hash_partition_matches_hashfn() {
        let a = Accel::rust();
        let words: Vec<u64> = (0..100).map(|i| i * 7 + 1).collect();
        let (fp, bk) = a.hash_partition(&words, 1, 16).unwrap();
        for i in 0..100 {
            assert_eq!(fp[i], hashfn::fp_words(&[words[i]]));
            assert_eq!(bk[i], hashfn::bucket_of(fp[i], 16));
        }
    }

    #[test]
    fn rust_prefix_scan_wraps() {
        let a = Accel::rust();
        let (scan, total) = a.prefix_scan(&[1, 2, 3, -1]).unwrap();
        assert_eq!(scan, vec![1, 3, 6, 5]);
        assert_eq!(total, 5);
        let (scan, _) = a.prefix_scan(&[i64::MAX, 1]).unwrap();
        assert_eq!(scan[1], i64::MIN); // wrapping
    }

    #[test]
    fn rust_reduce_identities_and_values() {
        let a = Accel::rust();
        let (s, mn, mx) = a.reduce_sumsq(&[]).unwrap();
        assert_eq!((s, mn, mx), (0, i64::MAX, i64::MIN));
        let (s, mn, mx) = a.reduce_sumsq(&[-3, 2, 5]).unwrap();
        assert_eq!((s, mn, mx), (9 + 4 + 25, -3, 5));
    }

    #[test]
    fn rust_bfs_expand_small() {
        let a = Accel::rust();
        let id = pancake::pack_perm(&[0, 1, 2]);
        let exp = a.bfs_expand(&[id], 3, 8).unwrap();
        assert_eq!(exp.packed.len(), 2);
        // flip2: (1,0,2); flip3: (2,1,0)
        assert_eq!(exp.packed[0], pancake::pack_perm(&[1, 0, 2]));
        assert_eq!(exp.packed[1], pancake::pack_perm(&[2, 1, 0]));
        for i in 0..2 {
            assert_eq!(exp.fp[i], hashfn::fp_words(&[exp.packed[i]]));
            assert!(exp.bucket[i] < 8);
        }
    }

    // ---- XLA vs Rust equivalence (skipped when artifacts are absent) ----

    #[test]
    fn xla_hash_partition_matches_rust_with_padding() {
        let Some(xla) = xla_accel() else { return };
        let rust = Accel::rust();
        // deliberately not a multiple of HASH_BATCH: exercises padding
        let words: Vec<u64> = (0..5003u64).map(|i| i.wrapping_mul(0x12345)).collect();
        let (f1, b1) = xla.hash_partition(&words, 1, 37).unwrap();
        let (f2, b2) = rust.hash_partition(&words, 1, 37).unwrap();
        assert_eq!(f1, f2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn xla_hash_partition_k2_matches_rust() {
        let Some(xla) = xla_accel() else { return };
        let rust = Accel::rust();
        let words: Vec<u64> = (0..2000u64).collect();
        let (f1, b1) = xla.hash_partition(&words, 2, 9).unwrap();
        let (f2, b2) = rust.hash_partition(&words, 2, 9).unwrap();
        assert_eq!(f1, f2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn xla_prefix_scan_matches_rust_across_batches() {
        let Some(xla) = xla_accel() else { return };
        let rust = Accel::rust();
        let x: Vec<i64> = (0..10_000).map(|i| (i as i64 % 97) - 48).collect();
        let (s1, t1) = xla.prefix_scan(&x).unwrap();
        let (s2, t2) = rust.prefix_scan(&x).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn xla_reduce_matches_rust_with_padding() {
        let Some(xla) = xla_accel() else { return };
        let rust = Accel::rust();
        let x: Vec<i64> = (0..5001).map(|i| (i as i64) - 2500).collect();
        assert_eq!(xla.reduce_sumsq(&x).unwrap(), rust.reduce_sumsq(&x).unwrap());
    }

    #[test]
    fn xla_bfs_expand_matches_rust() {
        let Some(xla) = xla_accel() else { return };
        let rust = Accel::rust();
        let n = 8;
        // a few hundred random perms, not a BFS_BATCH multiple
        let mut rng = crate::testutil::Rng::new(7);
        let frontier: Vec<u64> =
            (0..300).map(|_| pancake::pack_perm(&rng.permutation(n))).collect();
        let e1 = xla.bfs_expand(&frontier, n, 64).unwrap();
        let e2 = rust.bfs_expand(&frontier, n, 64).unwrap();
        assert_eq!(e1.packed, e2.packed);
        assert_eq!(e1.fp, e2.fp);
        assert_eq!(e1.bucket, e2.bucket);
    }
}
