//! 2×2×2 Rubik's cube ("pocket cube") by disk-based BFS.
//!
//! The second implicit-graph workload: Roomy's authors built it to run
//! exactly this family of computations (Kunkle & Cooperman's 26-moves
//! result for the 3×3×3 used the same disk-based BFS machinery). The
//! pocket cube is the laptop-scale member of the family: fixing the DBL
//! corner, the state space is 7! · 3⁶ = 3 674 160 states; in the
//! half-turn metric (U/R/F faces, quarter + half turns = 9 generators)
//! the diameter — "God's number" — is 11.
//!
//! State model: the 7 free corner cubies (URF, UFL, ULB, UBR, DFR, DLF,
//! DRB) each have a position (permutation of 0..7) and a twist
//! orientation in {0,1,2}; total twist ≡ 0 (mod 3). Packed into a u64 as
//! 7 position nibbles + 7 orientation crumbs.
//!
//! Correctness is self-validating: if the move tables were wrong, BFS
//! from the solved state would not close over exactly 3 674 160 states at
//! depth 11 with the known level profile (1, 9, 54, 321, ...).

use crate::accel::Accel;
use crate::constructs::bfs::{self, LevelStats};
use crate::error::Result;
use crate::roomy::Roomy;

/// Number of free corner cubies (DBL is fixed).
pub const NCORNERS: usize = 7;

/// |states| = 7! * 3^6.
pub const STATE_COUNT: u64 = 3_674_160;

/// God's number for the pocket cube in the half-turn metric.
pub const GODS_NUMBER: u64 = 11;

/// Known start of the HTM level profile (OEIS-adjacent; levels 0..=4).
pub const KNOWN_LEVEL_PREFIX: &[u64] = &[1, 9, 54, 321, 1847];

/// A pocket-cube state: position and twist of each free corner slot.
///
/// `perm[s]` = which cubie currently sits in slot `s`;
/// `orient[s]` = twist of that cubie (0, 1, 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Cube {
    pub perm: [u8; NCORNERS],
    pub orient: [u8; NCORNERS],
}

impl Cube {
    /// The solved cube.
    pub fn solved() -> Cube {
        Cube { perm: [0, 1, 2, 3, 4, 5, 6], orient: [0; NCORNERS] }
    }

    /// Pack into a u64: 7 position nibbles (bits 0..28) + 7 orientation
    /// crumbs (bits 28..42).
    pub fn pack(&self) -> u64 {
        let mut v = 0u64;
        for (i, &p) in self.perm.iter().enumerate() {
            v |= (p as u64) << (4 * i);
        }
        for (i, &o) in self.orient.iter().enumerate() {
            v |= (o as u64) << (28 + 2 * i);
        }
        v
    }

    /// Inverse of [`Cube::pack`].
    pub fn unpack(v: u64) -> Cube {
        let mut c = Cube::solved();
        for i in 0..NCORNERS {
            c.perm[i] = ((v >> (4 * i)) & 0xF) as u8;
            c.orient[i] = ((v >> (28 + 2 * i)) & 0x3) as u8;
        }
        c
    }

    /// Apply `mv`, returning the new state.
    pub fn apply(&self, mv: &Move) -> Cube {
        let mut out = Cube::solved();
        for s in 0..NCORNERS {
            // The cubie that lands in slot s comes from slot mv.src[s].
            let from = mv.src[s] as usize;
            out.perm[s] = self.perm[from];
            out.orient[s] = (self.orient[from] + mv.twist[s]) % 3;
        }
        out
    }

    /// Lehmer-style dense rank in `0..STATE_COUNT` (perm rank × 3⁶ +
    /// base-3 code of the first six orientations; the seventh is
    /// determined by the twist invariant).
    pub fn rank(&self) -> u64 {
        let pr = super::pancake::rank_perm(&self.perm);
        let mut orient_code = 0u64;
        for i in 0..6 {
            orient_code = orient_code * 3 + self.orient[i] as u64;
        }
        pr * 729 + orient_code
    }
}

/// One face turn: `src[s]` = slot whose cubie moves into slot `s`;
/// `twist[s]` = orientation added to the arriving cubie.
#[derive(Debug, Clone)]
pub struct Move {
    pub name: &'static str,
    pub src: [u8; NCORNERS],
    pub twist: [u8; NCORNERS],
}

/// Corner slot indices: 0=URF 1=UFL 2=ULB 3=UBR 4=DFR 5=DLF 6=DRB.
///
/// Base quarter turns (clockwise looking at the face). Twists follow the
/// standard convention: U turns twist nothing; R and F twist the four
/// corners they move by (2,1,2,1) in cycle order.
fn base_moves() -> Vec<Move> {
    // U cycles URF <- UBR <- ULB <- UFL <- URF
    let u = Move {
        name: "U",
        src: [3, 0, 1, 2, 4, 5, 6],
        twist: [0; 7],
    };
    // R cycles URF <- DFR <- DRB <- UBR; twists (URF,UBR,DRB,DFR)=(2,1,2,1)
    let r = Move {
        name: "R",
        src: [4, 1, 2, 0, 6, 5, 3],
        twist: [2, 0, 0, 1, 1, 0, 2],
    };
    // F cycles URF <- UFL <- DLF <- DFR; twists (URF,UFL,DLF,DFR)=(1,2,1,2)
    let f = Move {
        name: "F",
        src: [1, 5, 2, 3, 0, 4, 6],
        twist: [1, 2, 0, 0, 2, 1, 0],
    };
    vec![u, r, f]
}

/// Compose `m` applied twice / three times into single table moves.
fn repeat(m: &Move, times: usize, name: &'static str) -> Move {
    let mut src: [u8; NCORNERS] = [0, 1, 2, 3, 4, 5, 6];
    let mut twist = [0u8; NCORNERS];
    for _ in 0..times {
        let mut nsrc = [0u8; NCORNERS];
        let mut ntwist = [0u8; NCORNERS];
        for s in 0..NCORNERS {
            let mid = m.src[s] as usize;
            nsrc[s] = src[mid];
            ntwist[s] = (twist[mid] + m.twist[s]) % 3;
        }
        src = nsrc;
        twist = ntwist;
    }
    Move { name, src, twist }
}

/// The 9 half-turn-metric generators: U, U2, U', R, R2, R', F, F2, F'.
pub fn htm_moves() -> Vec<Move> {
    let base = base_moves();
    let mut out = Vec::with_capacity(9);
    for (m, n2, n3) in [
        (&base[0], "U2", "U'"),
        (&base[1], "R2", "R'"),
        (&base[2], "F2", "F'"),
    ] {
        out.push(repeat(m, 1, m.name));
        out.push(repeat(m, 2, n2));
        out.push(repeat(m, 3, n3));
    }
    out
}

/// All HTM neighbors of a packed state.
pub fn neighbors(code: u64, moves: &[Move], out: &mut Vec<u64>) {
    out.clear();
    let c = Cube::unpack(code);
    for mv in moves {
        out.push(c.apply(mv).pack());
    }
}

/// In-RAM reference BFS over the full pocket-cube group (seconds-scale;
/// used to validate the Roomy runs and as the RAM baseline in benches).
pub fn reference_bfs() -> Vec<u64> {
    let moves = htm_moves();
    let mut seen = vec![false; STATE_COUNT as usize];
    let start = Cube::solved();
    seen[start.rank() as usize] = true;
    let mut cur = vec![start.pack()];
    let mut levels = vec![1u64];
    let mut nbrs = Vec::new();
    while !cur.is_empty() {
        let mut next = Vec::new();
        for &code in &cur {
            neighbors(code, &moves, &mut nbrs);
            for &nb in &nbrs {
                let r = Cube::unpack(nb).rank() as usize;
                if !seen[r] {
                    seen[r] = true;
                    next.push(nb);
                }
            }
        }
        if next.is_empty() {
            break;
        }
        levels.push(next.len() as u64);
        cur = next;
    }
    levels
}

/// Disk-based BFS over the pocket-cube graph using the RoomyHashTable
/// driver (state → depth). `_accel` is accepted for signature parity with
/// the pancake app; cube expansion has no XLA kernel (documented in
/// DESIGN.md) and always runs on the Rust path.
pub fn roomy_bfs(r: &Roomy, _accel: &Accel) -> Result<LevelStats> {
    let moves = htm_moves();
    let start = Cube::solved().pack();
    bfs::bfs_hash_batched(r, "rubik", &[start], move |batch, out| {
        let mut nbrs = Vec::with_capacity(9);
        for &code in batch {
            neighbors(code, &moves, &mut nbrs);
            out.extend_from_slice(&nbrs);
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, tmpdir};

    #[test]
    fn pack_unpack_roundtrip() {
        prop_check("cube pack roundtrip", 30, |rng| {
            let mut c = Cube::solved();
            let p = rng.permutation(NCORNERS);
            c.perm.copy_from_slice(&p);
            for i in 0..NCORNERS {
                c.orient[i] = rng.below(3) as u8;
            }
            assert_eq!(Cube::unpack(c.pack()), c);
        });
    }

    #[test]
    fn moves_have_correct_order() {
        // U, R, F are 4-cycles: m^4 = identity; m2^2 = identity.
        let solved = Cube::solved();
        for m in base_moves() {
            let mut c = solved;
            for _ in 0..4 {
                c = c.apply(&m);
            }
            assert_eq!(c, solved, "{}^4 != id", m.name);
        }
        for m in htm_moves() {
            if m.name.ends_with('2') {
                let c = solved.apply(&m).apply(&m);
                assert_eq!(c, solved, "{}^2 != id", m.name);
            }
        }
    }

    #[test]
    fn quarter_and_inverse_cancel() {
        let moves = htm_moves();
        let solved = Cube::solved();
        // U then U' etc.
        for face in 0..3 {
            let q = &moves[face * 3];
            let inv = &moves[face * 3 + 2];
            assert_eq!(solved.apply(q).apply(inv), solved, "{} {}", q.name, inv.name);
        }
    }

    #[test]
    fn twist_invariant_preserved() {
        prop_check("twist sum mod 3 invariant", 20, |rng| {
            let moves = htm_moves();
            let mut c = Cube::solved();
            for _ in 0..rng.range(1, 30) {
                c = c.apply(&moves[rng.range(0, 9)]);
            }
            let total: u32 = c.orient.iter().map(|&o| o as u32).sum();
            assert_eq!(total % 3, 0, "twist invariant violated: {c:?}");
        });
    }

    #[test]
    fn rank_is_dense_and_injective_on_samples() {
        prop_check("cube rank bounds", 50, |rng| {
            let moves = htm_moves();
            let mut c = Cube::solved();
            for _ in 0..rng.range(0, 20) {
                c = c.apply(&moves[rng.range(0, 9)]);
            }
            assert!(c.rank() < STATE_COUNT);
        });
        // distinct small scrambles map to distinct ranks
        let moves = htm_moves();
        let solved = Cube::solved();
        let mut ranks = std::collections::HashSet::new();
        ranks.insert(solved.rank());
        for m in &moves {
            assert!(ranks.insert(solved.apply(m).rank()), "rank collision at depth 1");
        }
    }

    #[test]
    fn level1_is_nine_and_level2_is_54() {
        let moves = htm_moves();
        let solved = Cube::solved().pack();
        let mut l1 = std::collections::HashSet::new();
        let mut nbrs = Vec::new();
        neighbors(solved, &moves, &mut nbrs);
        for &n in &nbrs {
            assert_ne!(n, solved, "a generator fixed the solved state");
            l1.insert(n);
        }
        assert_eq!(l1.len(), 9);
        let mut l2 = std::collections::HashSet::new();
        for &c in &l1 {
            neighbors(c, &moves, &mut nbrs);
            for &n in &nbrs {
                if n != solved && !l1.contains(&n) {
                    l2.insert(n);
                }
            }
        }
        assert_eq!(l2.len(), 54);
    }

    #[test]
    #[ignore = "seconds-scale; covered by integration_bfs + benches"]
    fn reference_bfs_full_group() {
        let levels = reference_bfs();
        assert_eq!(levels.iter().sum::<u64>(), STATE_COUNT);
        assert_eq!(levels.len() as u64 - 1, GODS_NUMBER);
        assert_eq!(&levels[..KNOWN_LEVEL_PREFIX.len()], KNOWN_LEVEL_PREFIX);
    }

    #[test]
    fn roomy_bfs_shallow_agreement() {
        // Full disk BFS is covered by benches; here: run a bounded-depth
        // comparison by truncating with a small synthetic subgraph —
        // instead verify the first levels via the hash driver on the real
        // graph but a tiny cluster, stopping early is not supported, so
        // use the RAM reference prefix as the oracle for level counts of
        // a full run at n too large is slow; this test intentionally
        // checks the *generator* against the reference instead.
        let moves = htm_moves();
        let mut nbrs = Vec::new();
        let t = tmpdir("rubik_gen");
        let _ = t; // generator-only test; no disk needed
        // BFS 3 levels in RAM both ways (set-based vs reference prefix)
        let mut seen = std::collections::HashSet::new();
        let start = Cube::solved().pack();
        seen.insert(start);
        let mut cur = vec![start];
        let mut counts = vec![1u64];
        for _ in 0..3 {
            let mut next = vec![];
            for &c in &cur {
                neighbors(c, &moves, &mut nbrs);
                for &n in &nbrs {
                    if seen.insert(n) {
                        next.push(n);
                    }
                }
            }
            counts.push(next.len() as u64);
            cur = next;
        }
        assert_eq!(&counts[..], &KNOWN_LEVEL_PREFIX[..4]);
    }
}
