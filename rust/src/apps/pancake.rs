//! Pancake sorting by breadth-first search (paper §3).
//!
//! The state space is the symmetric group S_n; edges are prefix reversals
//! of length 2..=n. BFS from the identity yields, per level d, the number
//! of permutations needing exactly d flips; the deepest non-empty level is
//! the *pancake number* f(n).
//!
//! Encodings:
//! - **packed**: nibble-packed permutation in a `u64` (n ≤ 16) — the list
//!   and hash-table BFS variants use this, and it is the exact encoding
//!   the XLA `bfs_expand` kernel produces;
//! - **rank**: Lehmer-code rank in `0..n!` — the bit-array variant indexes
//!   a RoomyBitArray of n! one-bit "seen" flags with it.
//!
//! Three Roomy BFS variants (paper: "Three different solutions to the
//! pancake sorting problem, each using one of the three Roomy data
//! structures") plus [`reference_bfs`], an in-RAM baseline used both for
//! validation and as the RAM-vs-disk comparator in the benches.

use std::sync::Mutex;

use crate::accel::Accel;
use crate::constructs::bfs::{self, BfsOutcome, LevelStats, ResumableBfs};
use crate::error::Result;
use crate::roomy::Roomy;
use crate::storage::checkpoint::Checkpointable;

/// Known pancake numbers f(n) (max flips to sort any stack of n), n = 1..
/// OEIS A058986.
pub const PANCAKE_NUMBERS: &[u64] = &[0, 1, 3, 4, 5, 7, 8, 9, 10, 11, 13];

/// Pancake number for `n` if known (n ≤ 11).
pub fn pancake_number(n: usize) -> Option<u64> {
    PANCAKE_NUMBERS.get(n - 1).copied()
}

/// n! as u64 (n ≤ 20).
pub fn factorial(n: usize) -> u64 {
    (1..=n as u64).product()
}

// ---------------------------------------------------------------------
// Permutation encodings
// ---------------------------------------------------------------------

/// Nibble-pack a permutation of `0..n` (n ≤ 16).
pub fn pack_perm(perm: &[u8]) -> u64 {
    debug_assert!(perm.len() <= 16);
    let mut out = 0u64;
    for (i, &d) in perm.iter().enumerate() {
        out |= (d as u64) << (4 * i);
    }
    out
}

/// Unpack a nibble-packed permutation of size `n`.
pub fn unpack_perm(code: u64, n: usize) -> Vec<u8> {
    (0..n).map(|i| ((code >> (4 * i)) & 0xF) as u8).collect()
}

/// The identity permutation of size `n`, packed.
pub fn identity_packed(n: usize) -> u64 {
    pack_perm(&(0..n as u8).collect::<Vec<_>>())
}

/// Reverse the first `k` nibbles of a packed permutation — one pancake
/// flip, entirely in registers. Twin of the gather in the Pallas kernel.
pub fn flip_packed(code: u64, k: u32) -> u64 {
    debug_assert!(k >= 1);
    let bits = 4 * k;
    let mask: u64 = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut head = code & mask;
    // Reverse nibbles of `head` within k positions.
    let mut rev = 0u64;
    for _ in 0..k {
        rev = (rev << 4) | (head & 0xF);
        head >>= 4;
    }
    (code & !mask) | rev
}

/// All `n-1` prefix-reversal neighbors of a packed permutation.
pub fn neighbors_packed(code: u64, n: usize, out: &mut Vec<u64>) {
    out.clear();
    for k in 2..=n as u32 {
        out.push(flip_packed(code, k));
    }
}

// ---------------------------------------------------------------------
// Lehmer rank / unrank (array-variant state indexing)
// ---------------------------------------------------------------------

/// Rank of a permutation of `0..n` in `0..n!` (Lehmer code, O(n²) —
/// fine for n ≤ 16).
pub fn rank_perm(perm: &[u8]) -> u64 {
    let n = perm.len();
    let mut rank = 0u64;
    for i in 0..n {
        let mut smaller = 0u64;
        for j in (i + 1)..n {
            if perm[j] < perm[i] {
                smaller += 1;
            }
        }
        rank += smaller * factorial(n - 1 - i);
    }
    rank
}

/// Inverse of [`rank_perm`].
pub fn unrank_perm(mut rank: u64, n: usize) -> Vec<u8> {
    let mut digits: Vec<u8> = (0..n as u8).collect();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let f = factorial(n - 1 - i);
        let idx = (rank / f) as usize;
        rank %= f;
        out.push(digits.remove(idx));
    }
    out
}

// ---------------------------------------------------------------------
// In-RAM reference BFS (validation + RAM baseline)
// ---------------------------------------------------------------------

/// Level sizes of the pancake graph BFS from the identity, computed
/// entirely in RAM with a bitset over ranks. Practical to n = 11 or so.
pub fn reference_bfs(n: usize) -> Vec<u64> {
    assert!((1..=12).contains(&n), "reference BFS supports n <= 12");
    let total = factorial(n);
    let mut seen = vec![false; total as usize];
    let start = identity_packed(n);
    seen[rank_perm(&unpack_perm(start, n)) as usize] = true;
    let mut cur = vec![start];
    let mut levels = vec![1u64];
    let mut nbrs = Vec::new();
    while !cur.is_empty() {
        let mut next = Vec::new();
        for &code in &cur {
            neighbors_packed(code, n, &mut nbrs);
            for &nb in &nbrs {
                let r = rank_perm(&unpack_perm(nb, n)) as usize;
                if !seen[r] {
                    seen[r] = true;
                    next.push(nb);
                }
            }
        }
        if next.is_empty() {
            break;
        }
        levels.push(next.len() as u64);
        cur = next;
    }
    levels
}

// ---------------------------------------------------------------------
// Roomy BFS variants
// ---------------------------------------------------------------------

/// Which Roomy data structure drives the BFS (paper §3 final paragraph).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Structure {
    /// RoomyList of packed states: dedupe by external sort (`removeDupes`
    /// + `removeAll`) — the paper's §3 pseudocode.
    List,
    /// RoomyBitArray of n! seen-bits indexed by Lehmer rank.
    Array,
    /// RoomyHashTable state → BFS level.
    Hash,
}

/// Disk-based pancake BFS. Returns per-level state counts.
///
/// `accel` drives the batched frontier expansion (XLA or Rust — bit-exact
/// either way). Expansion is batched through [`Accel::bfs_expand`] for the
/// List/Hash variants; the Array variant expands per element to exercise
/// the per-element API as in the paper's pseudocode.
pub fn roomy_bfs(r: &Roomy, n: usize, structure: Structure, accel: &Accel) -> Result<LevelStats> {
    assert!((2..=16).contains(&n));
    match structure {
        Structure::List => bfs_list(r, n, accel),
        Structure::Hash => bfs_hash(r, n, accel),
        Structure::Array => bfs_array(r, n),
    }
}

/// Disk-based pancake BFS with a durable checkpoint after every level:
/// kill the process at any point and re-invoke with the same options to
/// continue from the last completed level — the resumed run's final state
/// and level profile are byte-identical to an uninterrupted one. All
/// three variants are resumable; the Array variant snapshots its
/// seen-bits bit array together with the current level list.
pub fn roomy_bfs_resumable(
    r: &Roomy,
    n: usize,
    structure: Structure,
    accel: &Accel,
    opts: &ResumableBfs<'_>,
) -> Result<BfsOutcome> {
    assert!((2..=16).contains(&n));
    let start = identity_packed(n);
    let nbuckets = r.cluster().nbuckets();
    let gen = |frontier: &[u64], out: &mut Vec<u64>| -> Result<()> {
        let exp = accel.bfs_expand(frontier, n, nbuckets)?;
        out.extend_from_slice(&exp.packed);
        Ok(())
    };
    match structure {
        Structure::List => bfs::bfs_list_resumable(r, "pancake", &[start], gen, opts),
        Structure::Hash => bfs::bfs_hash_resumable(r, "pancakeh", &[start], gen, opts),
        Structure::Array => bfs_array_impl(r, n, Some(opts)),
    }
}

/// RoomyList variant — the paper's §3 BFS pseudocode, with the frontier
/// expansion batched through the accel kernel.
fn bfs_list(r: &Roomy, n: usize, accel: &Accel) -> Result<LevelStats> {
    let start = identity_packed(n);
    bfs::bfs_list_batched(r, "pancake", &[start], |frontier, out| {
        let exp = accel.bfs_expand(frontier, n, r.cluster().nbuckets())?;
        out.extend_from_slice(&exp.packed);
        Ok(())
    })
}

/// RoomyHashTable variant: state → level, insert-if-absent emits to next.
fn bfs_hash(r: &Roomy, n: usize, accel: &Accel) -> Result<LevelStats> {
    let start = identity_packed(n);
    bfs::bfs_hash_batched(r, "pancakeh", &[start], |frontier, out| {
        let exp = accel.bfs_expand(frontier, n, r.cluster().nbuckets())?;
        out.extend_from_slice(&exp.packed);
        Ok(())
    })
}

/// RoomyBitArray variant: one seen-bit per Lehmer rank, frontier as lists
/// of packed states ("elements can be as small as one bit").
fn bfs_array(r: &Roomy, n: usize) -> Result<LevelStats> {
    match bfs_array_impl(r, n, None)? {
        BfsOutcome::Complete(stats) => Ok(stats),
        BfsOutcome::Suspended { .. } => unreachable!("no checkpoint hook without options"),
    }
}

/// The one RoomyBitArray BFS loop both [`bfs_array`] (ckpt = None) and
/// the resumable Array driver run (mirroring `bfs_list_impl` in
/// [`crate::constructs::bfs`]): the seen-bits bit array and the current
/// level list are snapshotted atomically after every completed level, so
/// a killed run resumes from level *k* with byte-identical final state
/// and level profile.
fn bfs_array_impl(r: &Roomy, n: usize, ckpt: Option<&ResumableBfs<'_>>) -> Result<BfsOutcome> {
    let total = factorial(n);
    let start = identity_packed(n);

    let mut resumed = None;
    if let Some(opts) = ckpt {
        if opts.manager.exists(&opts.tag) {
            let m = opts.manager.load_manifest(&opts.tag)?;
            let levels = bfs::app_levels(&m)?;
            let lev = bfs::app_u64(&m, "lev")? as u32;
            if m.app("done") == Some("1") {
                let total_seen = bfs::app_u64(&m, "total")?;
                return Ok(BfsOutcome::Complete(LevelStats { levels, total: total_seen }));
            }
            let res = opts.manager.restore(&opts.tag)?;
            let seen = r.restored_bit_array(&res, "pancakea_seen")?;
            let cur = r.restored_list::<u64>(&res, &format!("pancakea_lev{lev}"))?;
            resumed = Some((seen, cur, levels, lev));
        }
    }
    let (seen, mut cur, mut levels, mut lev) = match resumed {
        Some(state) => state,
        None => {
            let seen = r.bit_array("pancakea_seen", total, 1)?;
            // Mark the start.
            let mark = seen.register_update(|_i, _cur, _p: &()| 1);
            seen.update(rank_perm(&unpack_perm(start, n)), &(), mark)?;
            seen.sync()?;
            let cur = r.list::<u64>("pancakea_lev0")?;
            cur.add(&start)?;
            cur.sync()?;
            let levels = vec![1u64];
            if let Some(opts) = ckpt {
                bfs::save_level(opts, &[&seen as &dyn Checkpointable, &cur], 0, &levels)?;
            }
            (seen, cur, levels, 0u32)
        }
    };

    let mut completed_here = 0u32;
    while cur.size() > 0 {
        if bfs::should_suspend(ckpt, completed_here) {
            r.release_name(seen.name());
            r.release_name(cur.name());
            return Ok(BfsOutcome::Suspended { next_level: lev + 1 });
        }
        lev += 1;
        let next = r.list::<u64>(&format!("pancakea_lev{lev}"))?;
        // visit: set seen bit; newly-seen states go to `next` (the
        // passed value carries the packed state whose rank is `i`).
        let next_emit = next.clone();
        let visit = seen.register_update(move |_i, cur_bit, packed: &u64| {
            if cur_bit == 0 {
                next_emit.add(packed).expect("emit to next level");
            }
            1
        });
        // Expand the frontier: per-element neighbor generation (paper
        // pseudocode shape), issuing one delayed update per neighbor.
        let seen2 = seen.clone();
        let nbuf = Mutex::new(Vec::new());
        cur.map(move |&code| {
            let mut nbrs = nbuf.lock().unwrap();
            neighbors_packed(code, n, &mut nbrs);
            for &nb in nbrs.iter() {
                let rank = rank_perm(&unpack_perm(nb, n));
                seen2.update(rank, &nb, visit).expect("stage visit");
            }
        })?;
        seen.sync()?;
        next.sync()?;

        let name = cur.name().to_string();
        cur.destroy()?;
        r.release_name(&name);
        if next.size() > 0 {
            levels.push(next.size());
        }
        cur = next;
        if let Some(opts) = ckpt {
            bfs::save_level(opts, &[&seen as &dyn Checkpointable, &cur], lev, &levels)?;
        }
        completed_here += 1;
    }
    let name = cur.name().to_string();
    cur.destroy()?;
    r.release_name(&name);
    let seen_count = seen.count_value(1);
    if let Some(opts) = ckpt {
        bfs::save_final(opts, &[&seen as &dyn Checkpointable], lev, &levels, seen_count)?;
    }
    let name = seen.name().to_string();
    seen.destroy()?;
    r.release_name(&name);
    Ok(BfsOutcome::Complete(LevelStats { levels, total: seen_count }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, tmpdir};

    #[test]
    fn pack_unpack_roundtrip() {
        prop_check("pancake pack roundtrip", 30, |rng| {
            let n = rng.range(1, 17);
            let p = rng.permutation(n);
            assert_eq!(unpack_perm(pack_perm(&p), n), p);
        });
    }

    #[test]
    fn flip_packed_matches_slice_reverse() {
        prop_check("flip_packed vs slice reverse", 40, |rng| {
            let n = rng.range(2, 17);
            let p = rng.permutation(n);
            let k = rng.range(2, n + 1);
            let mut expect = p.clone();
            expect[..k].reverse();
            assert_eq!(
                flip_packed(pack_perm(&p), k as u32),
                pack_perm(&expect),
                "n={n} k={k} p={p:?}"
            );
        });
    }

    #[test]
    fn flip_is_involution() {
        prop_check("flip involution", 20, |rng| {
            let n = rng.range(2, 17);
            let code = pack_perm(&rng.permutation(n));
            let k = rng.range(2, n + 1) as u32;
            assert_eq!(flip_packed(flip_packed(code, k), k), code);
        });
    }

    #[test]
    fn rank_unrank_roundtrip_and_order() {
        for n in 1..=6 {
            let total = factorial(n);
            let mut seen = std::collections::HashSet::new();
            for r in 0..total {
                let p = unrank_perm(r, n);
                assert_eq!(rank_perm(&p), r, "n={n} r={r}");
                assert!(seen.insert(p), "duplicate perm at rank {r}");
            }
        }
        // identity has rank 0
        assert_eq!(rank_perm(&[0, 1, 2, 3]), 0);
    }

    #[test]
    fn reference_bfs_small_known_values() {
        // n=1: [1]; n=2: [1,1]; n=3: levels sum to 6, depth 3
        assert_eq!(reference_bfs(1), vec![1]);
        assert_eq!(reference_bfs(2), vec![1, 1]);
        let l3 = reference_bfs(3);
        assert_eq!(l3.iter().sum::<u64>(), 6);
        assert_eq!(l3.len() as u64 - 1, 3); // f(3) = 3
        assert_eq!(l3, vec![1, 2, 2, 1]);
    }

    #[test]
    fn reference_bfs_matches_pancake_numbers() {
        for n in 2..=7 {
            let levels = reference_bfs(n);
            assert_eq!(levels.iter().sum::<u64>(), factorial(n), "covers S_{n}");
            assert_eq!(
                levels.len() as u64 - 1,
                pancake_number(n).unwrap(),
                "pancake number f({n})"
            );
        }
    }

    #[test]
    fn roomy_bfs_list_matches_reference_n5() {
        let t = tmpdir("pk_list5");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let stats = roomy_bfs(&r, 5, Structure::List, &Accel::rust()).unwrap();
        assert_eq!(stats.levels, reference_bfs(5));
        assert_eq!(stats.total, factorial(5));
    }

    #[test]
    fn roomy_bfs_hash_matches_reference_n5() {
        let t = tmpdir("pk_hash5");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let stats = roomy_bfs(&r, 5, Structure::Hash, &Accel::rust()).unwrap();
        assert_eq!(stats.levels, reference_bfs(5));
        assert_eq!(stats.total, factorial(5));
    }

    #[test]
    fn roomy_bfs_array_matches_reference_n5() {
        let t = tmpdir("pk_arr5");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let stats = roomy_bfs(&r, 5, Structure::Array, &Accel::rust()).unwrap();
        assert_eq!(stats.levels, reference_bfs(5));
        assert_eq!(stats.total, factorial(5));
    }

    #[test]
    fn roomy_bfs_resumable_kill_and_resume_matches_reference_n6() {
        let t = tmpdir("pk_res6");
        // session 1: killed after two completed levels
        {
            let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
            let mgr = r.checkpoints().unwrap();
            let opts = ResumableBfs {
                manager: &mgr,
                tag: "pk6".into(),
                stop_after_levels: Some(2),
            };
            let out = roomy_bfs_resumable(&r, 6, Structure::List, &Accel::rust(), &opts).unwrap();
            assert_eq!(out, BfsOutcome::Suspended { next_level: 3 });
        }
        // session 2: fresh process over the same root finishes the search
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let mgr = r.checkpoints().unwrap();
        let out = roomy_bfs_resumable(
            &r,
            6,
            Structure::List,
            &Accel::rust(),
            &ResumableBfs::new(&mgr, "pk6"),
        )
        .unwrap();
        match out {
            BfsOutcome::Complete(stats) => {
                assert_eq!(stats.levels, reference_bfs(6));
                assert_eq!(stats.total, factorial(6));
            }
            other => panic!("expected Complete, got {other:?}"),
        }
    }

    #[test]
    fn roomy_bfs_resumable_array_kill_and_resume_matches_reference_n6() {
        let t = tmpdir("pk_res_arr6");
        // session 1: killed after two completed levels
        {
            let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
            let mgr = r.checkpoints().unwrap();
            let opts = ResumableBfs {
                manager: &mgr,
                tag: "pkarr6".into(),
                stop_after_levels: Some(2),
            };
            let out =
                roomy_bfs_resumable(&r, 6, Structure::Array, &Accel::rust(), &opts).unwrap();
            assert_eq!(out, BfsOutcome::Suspended { next_level: 3 });
        }
        // session 2: fresh process over the same root finishes the search
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let mgr = r.checkpoints().unwrap();
        let out = roomy_bfs_resumable(
            &r,
            6,
            Structure::Array,
            &Accel::rust(),
            &ResumableBfs::new(&mgr, "pkarr6"),
        )
        .unwrap();
        match out {
            BfsOutcome::Complete(stats) => {
                assert_eq!(stats.levels, reference_bfs(6));
                assert_eq!(stats.total, factorial(6));
            }
            other => panic!("expected Complete, got {other:?}"),
        }
    }

    #[test]
    fn roomy_bfs_all_variants_agree_n6() {
        let t = tmpdir("pk_all6");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let expect = reference_bfs(6);
        for (i, s) in [Structure::List, Structure::Hash, Structure::Array]
            .into_iter()
            .enumerate()
        {
            // fresh namespace per variant
            let t2 = tmpdir(&format!("pk_all6_{i}"));
            let r2 = if i == 0 {
                r.clone()
            } else {
                Roomy::open(crate::RoomyConfig::for_testing(t2.path())).unwrap()
            };
            let stats = roomy_bfs(&r2, 6, s, &Accel::rust()).unwrap();
            assert_eq!(stats.levels, expect, "variant {s:?}");
        }
    }
}
