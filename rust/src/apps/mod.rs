//! Applications built on the Roomy API.
//!
//! [`pancake`] is the paper's flagship workload: solving the pancake
//! sorting problem ("how many prefix reversals suffice to sort any stack
//! of n pancakes?") by disk-based breadth-first search over the implicit
//! Cayley graph of prefix reversals — with all three data-structure
//! variants the paper mentions, plus an in-RAM reference baseline.

pub mod pancake;
pub mod rubik;
