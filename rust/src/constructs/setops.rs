//! Set operations over RoomyLists (paper §3).
//!
//! A RoomyList becomes a set by `removeDupes`; union/difference/
//! intersection are then built from `addAll`, `removeAll` and
//! `removeDupes` exactly as the paper's code fragments do. The paper notes
//! intersection is sub-optimal with the current primitives ("may become a
//! Roomy primitive in the future") — we reproduce the paper's
//! union-minus-differences construction and also provide the obvious
//! sorted-merge primitive as the "future work" extension, which E5
//! benchmarks against it.
//!
//! These operators compose list primitives (`add_all` / `remove_all` /
//! `remove_dupes`), so their inner loops are the external-sort merge
//! loops in [`crate::storage::extsort`]: the word-wise compare/equality
//! kernels and the batched fingerprint routing there are what these
//! union/intersect/diff paths actually execute per record. Dense sets
//! represented as 1-bit [`crate::roomy::RoomyBitArray`]s get the same
//! algebra as wide word sweeps via
//! [`combine_from`](crate::roomy::bitarray::RoomyBitArray::combine_from).

use crate::error::Result;
use crate::roomy::{Element, Roomy, RoomyList};

/// Convert a list (possibly with duplicates) into a set.
pub fn to_set<T: Element>(list: &RoomyList<T>) -> Result<()> {
    list.remove_dupes()
}

/// Set union in place: `a = a ∪ b` (paper: addAll + removeDupes).
pub fn union_into<T: Element>(a: &RoomyList<T>, b: &RoomyList<T>) -> Result<()> {
    a.add_all(b)?;
    a.remove_dupes()
}

/// Set difference in place: `a = a - b` (paper: just removeAll,
/// assuming both are sets).
pub fn difference_into<T: Element>(a: &RoomyList<T>, b: &RoomyList<T>) -> Result<()> {
    a.remove_all(b)
}

/// Set intersection via the paper's construction:
/// `C = (A ∪ B) - (A - B) - (B - A)`, using three temporary sets.
/// Returns a new list named `name`.
pub fn intersection<T: Element>(
    r: &Roomy,
    name: &str,
    a: &RoomyList<T>,
    b: &RoomyList<T>,
) -> Result<RoomyList<T>> {
    // create three temporary sets
    let a_and_b = r.list::<T>(&format!("{name}-tmpAandB"))?;
    let a_minus_b = r.list::<T>(&format!("{name}-tmpAminusB"))?;
    let b_minus_a = r.list::<T>(&format!("{name}-tmpBminusA"))?;
    let c = r.list::<T>(name)?;

    a_and_b.add_all(a)?;
    a_and_b.add_all(b)?;
    a_and_b.remove_dupes()?;

    a_minus_b.add_all(a)?;
    a_minus_b.remove_all(b)?;

    b_minus_a.add_all(b)?;
    b_minus_a.remove_all(a)?;

    // compute intersection
    c.add_all(&a_and_b)?;
    c.remove_all(&a_minus_b)?;
    c.remove_all(&b_minus_a)?;

    for (tmp, suffix) in [
        (a_and_b, "tmpAandB"),
        (a_minus_b, "tmpAminusB"),
        (b_minus_a, "tmpBminusA"),
    ] {
        tmp.destroy()?;
        r.release_name(&format!("{name}-{suffix}"));
    }
    Ok(c)
}

/// "Future work" intersection primitive: per-shard sorted-merge keep of
/// common elements — one sort of each side instead of the paper's three
/// temporaries. Both inputs must already be sets (deduped).
pub fn intersection_primitive<T: Element>(
    r: &Roomy,
    name: &str,
    a: &RoomyList<T>,
    b: &RoomyList<T>,
) -> Result<RoomyList<T>> {
    // C = A - (A - B): two removeAlls but no unions, exploiting sorted
    // shards directly.
    let c = r.list::<T>(name)?;
    let a_minus_b = r.list::<T>(&format!("{name}-tmpD"))?;
    a_minus_b.add_all(a)?;
    a_minus_b.remove_all(b)?;
    c.add_all(a)?;
    c.remove_all(&a_minus_b)?;
    a_minus_b.destroy()?;
    r.release_name(&format!("{name}-tmpD"));
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, tmpdir};
    use std::collections::BTreeSet;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    fn fill(l: &RoomyList<u64>, vals: &[u64]) {
        for v in vals {
            l.add(v).unwrap();
        }
        l.sync().unwrap();
    }

    fn as_btree(l: &RoomyList<u64>) -> BTreeSet<u64> {
        l.collect().unwrap().into_iter().collect()
    }

    #[test]
    fn union_difference_paper_fragments() {
        let t = tmpdir("set_union");
        let r = mk(t.path());
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        fill(&a, &[1, 2, 3, 3]);
        fill(&b, &[3, 4, 5]);
        to_set(&a).unwrap();
        to_set(&b).unwrap();

        union_into(&a, &b).unwrap();
        assert_eq!(as_btree(&a), BTreeSet::from([1, 2, 3, 4, 5]));

        difference_into(&a, &b).unwrap();
        assert_eq!(as_btree(&a), BTreeSet::from([1, 2]));
    }

    #[test]
    fn intersection_paper_construction() {
        let t = tmpdir("set_inter");
        let r = mk(t.path());
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        fill(&a, &[1, 2, 3, 4, 5]);
        fill(&b, &[4, 5, 6, 7]);
        let c = intersection(&r, "c", &a, &b).unwrap();
        assert_eq!(as_btree(&c), BTreeSet::from([4, 5]));
        // inputs untouched
        assert_eq!(a.size(), 5);
        assert_eq!(b.size(), 4);
    }

    #[test]
    fn intersection_empty_and_disjoint() {
        let t = tmpdir("set_disjoint");
        let r = mk(t.path());
        let a = r.list::<u64>("a").unwrap();
        let b = r.list::<u64>("b").unwrap();
        fill(&a, &[1, 2]);
        // b stays empty
        let c = intersection(&r, "c", &a, &b).unwrap();
        assert_eq!(c.size(), 0);
        let d = r.list::<u64>("b2").unwrap();
        fill(&d, &[9, 10]);
        let e = intersection(&r, "e", &a, &d).unwrap();
        assert_eq!(e.size(), 0);
    }

    #[test]
    fn intersection_primitive_matches_paper_construction() {
        prop_check("intersection variants agree", 6, |rng| {
            let t = tmpdir("set_prop");
            let r = mk(t.path());
            let mk_vals = |rng: &mut crate::testutil::Rng| -> Vec<u64> {
                let n = rng.range(0, 60);
                (0..n).map(|_| rng.below(40)).collect()
            };
            let va = mk_vals(rng);
            let vb = mk_vals(rng);
            let a = r.list::<u64>("a").unwrap();
            let b = r.list::<u64>("b").unwrap();
            fill(&a, &va);
            fill(&b, &vb);
            to_set(&a).unwrap();
            to_set(&b).unwrap();
            let c1 = intersection(&r, "c1", &a, &b).unwrap();
            let c2 = intersection_primitive(&r, "c2", &a, &b).unwrap();
            let expect: BTreeSet<u64> = {
                let sa: BTreeSet<u64> = va.iter().copied().collect();
                let sb: BTreeSet<u64> = vb.iter().copied().collect();
                sa.intersection(&sb).copied().collect()
            };
            assert_eq!(as_btree(&c1), expect);
            assert_eq!(as_btree(&c2), expect);
        });
    }

    #[test]
    fn model_check_against_std_sets() {
        prop_check("set algebra model", 6, |rng| {
            let t = tmpdir("set_model");
            let r = mk(t.path());
            let va: Vec<u64> = (0..rng.range(0, 80)).map(|_| rng.below(50)).collect();
            let vb: Vec<u64> = (0..rng.range(0, 80)).map(|_| rng.below(50)).collect();
            let a = r.list::<u64>("a").unwrap();
            let b = r.list::<u64>("b").unwrap();
            fill(&a, &va);
            fill(&b, &vb);
            to_set(&a).unwrap();
            to_set(&b).unwrap();
            let sa: BTreeSet<u64> = va.iter().copied().collect();
            let sb: BTreeSet<u64> = vb.iter().copied().collect();
            if rng.chance(0.5) {
                union_into(&a, &b).unwrap();
                let expect: BTreeSet<u64> = sa.union(&sb).copied().collect();
                assert_eq!(as_btree(&a), expect);
            } else {
                difference_into(&a, &b).unwrap();
                let expect: BTreeSet<u64> = sa.difference(&sb).copied().collect();
                assert_eq!(as_btree(&a), expect);
            }
        });
    }
}
