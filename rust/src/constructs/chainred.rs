//! Chain reduction (paper §3): combine each array element with the one
//! before it, all reads taken from the pre-sync state:
//!
//! ```text
//! for i = 1 to N-1:  a[i] = combine(a[i], a[i-1])   // old values on RHS
//! ```
//!
//! Implemented exactly as the paper's pseudocode: a `map` over the array
//! issues one delayed `update` per successor element, carrying the old
//! value as the passed datum; `sync` applies the batch. Determinism comes
//! from Roomy's guarantee that no delayed update executes before `sync`
//! (scatter-gather).

use crate::error::Result;
use crate::roomy::{Element, RoomyArray};

/// In-place chain reduction: `a[i] = combine(a[i], a[i-1])` over pre-sync
/// values, for all `i >= 1`.
pub fn chain_reduce<T: Element>(
    ra: &RoomyArray<T>,
    combine: impl Fn(&T, &T) -> T + Send + Sync + 'static,
) -> Result<()> {
    let n = ra.len();
    // doUpdate: new a[i] = combine(old a[i], old a[i-1]).
    let do_update =
        ra.register_update(move |_i, v: &mut T, prev: &T| *v = combine(v, prev));
    // callUpdate: mapped over the array, issues the delayed updates.
    let ra2 = ra.clone();
    ra.map(move |i, v| {
        if i + 1 < n {
            ra2.update(i + 1, v, do_update).expect("stage chain update");
        }
    })?;
    ra.sync()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::{prop_check, tmpdir};

    #[test]
    fn paper_example_ints() {
        let t = tmpdir("chain_int");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let n = 100u64;
        let ra = r.array::<i64>("a", n, 0).unwrap();
        ra.map_update(|i, v| *v = i as i64 + 1).unwrap();
        chain_reduce(&ra, |a, b| a + b).unwrap();
        // a[i] = (i+1) + i for i >= 1; a[0] unchanged
        assert_eq!(ra.fetch(0).unwrap(), 1);
        for i in 1..n {
            assert_eq!(ra.fetch(i).unwrap(), (2 * i + 1) as i64, "i={i}");
        }
    }

    #[test]
    fn deterministic_uses_old_values_only() {
        // With a non-commutative combine the result distinguishes old-value
        // semantics from sequential in-place semantics.
        let t = tmpdir("chain_det");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let ra = r.array::<i64>("a", 4, 0).unwrap();
        ra.map_update(|i, v| *v = 10i64.pow(i as u32)).unwrap(); // 1,10,100,1000
        chain_reduce(&ra, |a, b| a - b).unwrap();
        // old-value semantics: a = [1, 10-1, 100-10, 1000-100]
        let got: Vec<i64> = (0..4).map(|i| ra.fetch(i).unwrap()).collect();
        assert_eq!(got, vec![1, 9, 90, 900]);
    }

    #[test]
    fn prop_matches_serial_model() {
        prop_check("chain reduce vs serial", 8, |rng| {
            let t = tmpdir("chain_prop");
            let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
            let n = rng.range(1, 120) as u64;
            let vals: Vec<i64> = (0..n).map(|_| rng.range_i64(-100, 100)).collect();
            let ra = r.array::<i64>("a", n, 0).unwrap();
            let vals2 = vals.clone();
            ra.map_update(move |i, v| *v = vals2[i as usize]).unwrap();
            chain_reduce(&ra, |a, b| a.wrapping_add(*b)).unwrap();
            // serial model over old values
            let mut expect = vals.clone();
            for i in (1..n as usize).rev() {
                expect[i] = vals[i].wrapping_add(vals[i - 1]);
            }
            for i in 0..n {
                assert_eq!(ra.fetch(i).unwrap(), expect[i as usize]);
            }
        });
    }
}
