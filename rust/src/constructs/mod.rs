//! The programming constructs of paper §3, built on the Roomy primitives:
//! map, reduce, set operations, chain reduction, parallel prefix, pair
//! reduction, and breadth-first search.
//!
//! Map and reduce are primitive operations on the structures themselves
//! ([`crate::roomy`]); the modules here add the composite constructs and a
//! few batched variants that route their inner loops through the
//! [`crate::accel`] kernels.

pub mod bfs;
pub mod chainred;
pub mod mapreduce;
pub mod pairred;
pub mod prefix;
pub mod setops;
