//! Pair reduction (paper §3): apply a function to every ordered pair of
//! elements of a RoomyArray:
//!
//! ```text
//! for i = 0 to N-1:
//!   for j = 0 to N-1:
//!     f(a[i], a[j])
//! ```
//!
//! As in the paper: the `map` over the array is the outer loop, each
//! mapped element issues N delayed `access` operations (the inner loop)
//! carrying the outer value as the passed datum, and the access function
//! applies `f` to the pair. `f` may itself issue delayed ops on other
//! structures (the paper's example adds each pair to a RoomyList).

use crate::error::Result;
use crate::roomy::{Element, RoomyArray};

/// Apply `f((j, a_j), (i, a_i))` for every ordered pair — `j` is the
/// inner index, `i` the outer, matching the paper's `doAccess(innerIndex,
/// innerVal, outerVal)` shape (we additionally pass the outer index).
pub fn pair_reduction<T: Element>(
    ra: &RoomyArray<T>,
    f: impl Fn(u64, &T, u64, &T) + Send + Sync + 'static,
) -> Result<()> {
    let n = ra.len();
    // doAccess: applies f to (inner, outer).
    let do_access = ra.register_access(move |j, inner: &T, passed: &(u64, T)| {
        f(j, inner, passed.0, &passed.1)
    });
    // callAccess: the inner loop, issuing one delayed access per element.
    let ra2 = ra.clone();
    ra.map(move |i, outer| {
        let passed = (i, outer.clone());
        for j in 0..n {
            ra2.access(j, &passed, do_access).expect("stage pair access");
        }
    })?;
    ra.sync()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::tmpdir;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn visits_all_ordered_pairs() {
        let t = tmpdir("pair_all");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let n = 12u64;
        let ra = r.array::<u64>("a", n, 0).unwrap();
        ra.map_update(|i, v| *v = i + 1).unwrap();
        let count = Arc::new(AtomicU64::new(0));
        let sum = Arc::new(AtomicU64::new(0));
        let (c2, s2) = (count.clone(), sum.clone());
        pair_reduction(&ra, move |_j, inner, _i, outer| {
            c2.fetch_add(1, Ordering::Relaxed);
            s2.fetch_add(inner * outer, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), n * n);
        // sum over all pairs (i+1)(j+1) = (sum 1..n)^2
        let s: u64 = (1..=n).sum();
        assert_eq!(sum.load(Ordering::Relaxed), s * s);
    }

    #[test]
    fn paper_example_pairs_into_list() {
        let t = tmpdir("pair_list");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let n = 5u64;
        let ra = r.array::<u32>("a", n, 0).unwrap();
        ra.map_update(|i, v| *v = 10 * (i as u32 + 1)).unwrap();
        let rl = r.list::<(u32, u32)>("pairs").unwrap();
        let rl2 = rl.clone();
        pair_reduction(&ra, move |_j, inner: &u32, _i, outer: &u32| {
            rl2.add(&(*inner, *outer)).expect("add pair");
        })
        .unwrap();
        rl.sync().unwrap();
        assert_eq!(rl.size(), n * n);
        // spot-check one pair exists
        let pairs = rl.collect().unwrap();
        assert!(pairs.contains(&(10, 50)));
        assert!(pairs.contains(&(50, 10)));
    }

    #[test]
    fn indices_are_correct() {
        let t = tmpdir("pair_idx");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let n = 4u64;
        let ra = r.array::<u64>("a", n, 0).unwrap();
        ra.map_update(|i, v| *v = 100 + i).unwrap();
        let seen = Arc::new(std::sync::Mutex::new(std::collections::HashSet::new()));
        let s2 = seen.clone();
        pair_reduction(&ra, move |j, inner, i, outer| {
            assert_eq!(*inner, 100 + j, "inner value matches inner index");
            assert_eq!(*outer, 100 + i, "outer value matches outer index");
            s2.lock().unwrap().insert((i, j));
        })
        .unwrap();
        assert_eq!(seen.lock().unwrap().len(), (n * n) as usize);
    }
}
