//! Map and reduce construct helpers (paper §3's two primitive constructs),
//! including the paper's worked examples and accel-batched variants.

use std::sync::Mutex;

use crate::accel::Accel;
use crate::error::Result;
use crate::roomy::{Element, RoomyArray, RoomyHashTable, RoomyList};

/// The paper's map example: convert a RoomyArray into a RoomyHashTable
/// with array indices as keys and the elements as values.
pub fn array_to_hashtable<T: Element>(
    ra: &RoomyArray<T>,
    rht: &RoomyHashTable<u64, T>,
) -> Result<()> {
    // makePair, mapped over ra: issue a delayed insert per element.
    let rht2 = rht.clone();
    ra.map(move |i, element| {
        rht2.insert(&i, element).expect("stage insert");
    })?;
    // Perform map, then complete delayed inserts.
    rht.sync()
}

/// The paper's reduce example: sum of squares of a RoomyList of ints.
pub fn sum_of_squares(rl: &RoomyList<i64>) -> Result<i64> {
    // mergeElt / mergeResults from the paper.
    rl.reduce(
        || 0i64,
        |sum, element| sum.wrapping_add(element.wrapping_mul(*element)),
        |sum1, sum2| sum1.wrapping_add(sum2),
    )
}

/// Accel-batched sum of squares: elements are streamed into batches and
/// reduced by the L1 kernel ([`Accel::reduce_sumsq`]); partials merge in
/// L3. Bit-identical to [`sum_of_squares`] (wrapping arithmetic).
pub fn sum_of_squares_accel(rl: &RoomyList<i64>, accel: &Accel) -> Result<i64> {
    const BATCH: usize = 4096;
    let state: Mutex<(Vec<i64>, i64)> = Mutex::new((Vec::with_capacity(BATCH), 0));
    rl.map(|&v| {
        let mut g = state.lock().unwrap();
        g.0.push(v);
        if g.0.len() >= BATCH {
            let (batch, acc) = &mut *g;
            let (s, _, _) = accel.reduce_sumsq(batch).expect("reduce batch");
            *acc = acc.wrapping_add(s);
            batch.clear();
        }
    })?;
    let mut g = state.into_inner().unwrap();
    let (s, _, _) = accel.reduce_sumsq(&g.0)?;
    g.1 = g.1.wrapping_add(s);
    Ok(g.1)
}

/// Reduce helper: the k largest elements of a list (the paper's "result
/// type differs from element type" example).
pub fn k_largest<T: Element + Ord>(rl: &RoomyList<T>, k: usize) -> Result<Vec<T>> {
    let merge_two = move |mut a: Vec<T>, b: Vec<T>| {
        a.extend(b);
        a.sort_unstable_by(|x, y| y.cmp(x));
        a.truncate(k);
        a
    };
    rl.reduce(
        Vec::new,
        move |mut acc, elt| {
            acc.push(elt.clone());
            acc.sort_unstable_by(|x, y| y.cmp(x));
            acc.truncate(k);
            acc
        },
        merge_two,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    #[test]
    fn paper_map_example() {
        let t = tmpdir("mr_map");
        let r = mk(t.path());
        let ra = r.array::<u32>("a", 50, 0).unwrap();
        ra.map_update(|i, v| *v = (i * 3) as u32).unwrap();
        let rht = r.hash_table::<u64, u32>("h").unwrap();
        array_to_hashtable(&ra, &rht).unwrap();
        assert_eq!(rht.size(), 50);
        assert_eq!(rht.fetch(&7).unwrap(), Some(21));
        assert_eq!(rht.fetch(&49).unwrap(), Some(147));
    }

    #[test]
    fn paper_reduce_example() {
        let t = tmpdir("mr_reduce");
        let r = mk(t.path());
        let rl = r.list::<i64>("l").unwrap();
        for v in 1..=100i64 {
            rl.add(&v).unwrap();
        }
        rl.sync().unwrap();
        let expect: i64 = (1..=100i64).map(|v| v * v).sum();
        assert_eq!(sum_of_squares(&rl).unwrap(), expect);
        assert_eq!(sum_of_squares_accel(&rl, &Accel::rust()).unwrap(), expect);
    }

    #[test]
    fn accel_batched_matches_plain_on_large_input() {
        let t = tmpdir("mr_accel");
        let r = mk(t.path());
        let rl = r.list::<i64>("l").unwrap();
        for v in 0..10_000i64 {
            rl.add(&(v - 5000)).unwrap();
        }
        rl.sync().unwrap();
        let a = sum_of_squares(&rl).unwrap();
        let b = sum_of_squares_accel(&rl, &Accel::rust()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn k_largest_finds_top() {
        let t = tmpdir("mr_klargest");
        let r = mk(t.path());
        let rl = r.list::<u64>("l").unwrap();
        for v in 0..1000u64 {
            rl.add(&(v * 7919 % 1000)).unwrap();
        }
        rl.sync().unwrap();
        let top = k_largest(&rl, 3).unwrap();
        assert_eq!(top, vec![999, 998, 997]);
    }

    #[test]
    fn k_largest_short_list() {
        let t = tmpdir("mr_kshort");
        let r = mk(t.path());
        let rl = r.list::<u64>("l").unwrap();
        rl.add(&5).unwrap();
        rl.sync().unwrap();
        assert_eq!(k_largest(&rl, 10).unwrap(), vec![5]);
    }
}
