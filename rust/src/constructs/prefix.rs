//! Parallel prefix (paper §3): inclusive prefix combine over a RoomyArray
//! in `⌈log2 N⌉` chain-reduction rounds:
//!
//! ```text
//! for (k = 1; k < N; k *= 2):
//!     if i - k >= 0:  a[i] = combine(a[i], a[i-k])   // old values per round
//! ```
//!
//! Each round is one map (issue updates with stride `k`) + one sync — the
//! Hillis–Steele scan expressed in Roomy's delayed-update model.
//!
//! [`prefix_scan_array`] is the accelerated alternative for `i64` sums:
//! the textbook two-pass parallel scan over buckets, dispatched through
//! the worker pool ([`crate::runtime::pool`]) — pass 1 scans every bucket
//! locally (concurrent, one L1 scan-kernel call per bucket) and collects
//! bucket totals, a cheap serial pass turns totals into per-bucket
//! carries, and pass 2 adds each bucket's carry (concurrent). Two passes
//! over the disk instead of `log N`, and both passes scale with
//! `num_workers` — the kind of constant-factor win DESIGN.md's E7
//! ablation measures.

use crate::accel::Accel;
use crate::error::Result;
use crate::roomy::{Element, RoomyArray};

/// Inclusive parallel prefix: `a[i] = combine(a[i], ..., a[0])` via log
/// rounds of strided chain reductions.
pub fn parallel_prefix<T: Element>(
    ra: &RoomyArray<T>,
    combine: impl Fn(&T, &T) -> T + Send + Sync + 'static + Clone,
) -> Result<()> {
    let n = ra.len();
    let mut k = 1u64;
    while k < n {
        let comb = combine.clone();
        let do_update =
            ra.register_update(move |_i, v: &mut T, prev: &T| *v = comb(v, prev));
        let ra2 = ra.clone();
        let stride = k;
        ra.map(move |i, v| {
            if i + stride < n {
                ra2.update(i + stride, v, do_update).expect("stage prefix update");
            }
        })?;
        ra.sync()?;
        k *= 2;
    }
    Ok(())
}

/// Accelerated inclusive prefix *sum* for `i64` arrays: two pooled
/// per-bucket passes (local scan, then carry add) around one cheap serial
/// carry computation. RAM use stays one bucket per pool worker.
pub fn prefix_scan_array(ra: &RoomyArray<i64>, accel: &Accel) -> Result<()> {
    let nb = ra.bucket_count();
    // Pass 1 (pooled, with cross-task prefetch hints on the bucket
    // files): scan each bucket in place, return its total.
    let totals: Vec<i64> = ra.cluster().run_buckets_hinted(
        "prefix.scan",
        |b| (b < nb).then(|| ra.bucket_rel(b)),
        |b, _disk| {
            if b >= nb {
                return Ok(0i64);
            }
            let data = ra.read_bucket_i64(b)?;
            if data.is_empty() {
                return Ok(0i64);
            }
            let (scanned, total) = accel.prefix_scan(&data)?;
            ra.write_bucket_i64(b, &scanned)?;
            Ok(total)
        },
    )?;
    // Serial: exclusive prefix of bucket totals = per-bucket carries.
    let mut carries = Vec::with_capacity(totals.len());
    let mut carry = 0i64;
    for t in &totals {
        carries.push(carry);
        carry = carry.wrapping_add(*t);
    }
    // Pass 2 (pooled): add each bucket's carry.
    ra.cluster().run_buckets_hinted(
        "prefix.carry",
        |b| {
            (b < nb && carries.get(b as usize).copied().unwrap_or(0) != 0)
                .then(|| ra.bucket_rel(b))
        },
        |b, _disk| {
            let c = carries.get(b as usize).copied().unwrap_or(0);
            if b >= nb || c == 0 {
                return Ok(());
            }
            let mut data = ra.read_bucket_i64(b)?;
            if data.is_empty() {
                return Ok(());
            }
            for v in data.iter_mut() {
                *v = v.wrapping_add(c);
            }
            ra.write_bucket_i64(b, &data)
        },
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roomy::Roomy;
    use crate::testutil::{prop_check, tmpdir};

    fn fill(ra: &RoomyArray<i64>, vals: &[i64]) {
        let v = vals.to_vec();
        ra.map_update(move |i, x| *x = v[i as usize]).unwrap();
    }

    fn expect_prefix(vals: &[i64]) -> Vec<i64> {
        let mut acc = 0i64;
        vals.iter()
            .map(|v| {
                acc = acc.wrapping_add(*v);
                acc
            })
            .collect()
    }

    #[test]
    fn small_sum_prefix() {
        let t = tmpdir("prefix_small");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let vals: Vec<i64> = (1..=20).collect();
        let ra = r.array::<i64>("a", vals.len() as u64, 0).unwrap();
        fill(&ra, &vals);
        parallel_prefix(&ra, |a, b| a.wrapping_add(*b)).unwrap();
        for (i, e) in expect_prefix(&vals).into_iter().enumerate() {
            assert_eq!(ra.fetch(i as u64).unwrap(), e, "i={i}");
        }
    }

    #[test]
    fn non_power_of_two_length() {
        let t = tmpdir("prefix_np2");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let vals: Vec<i64> = (0..37).map(|i| i * i - 7).collect();
        let ra = r.array::<i64>("a", 37, 0).unwrap();
        fill(&ra, &vals);
        parallel_prefix(&ra, |a, b| a.wrapping_add(*b)).unwrap();
        for (i, e) in expect_prefix(&vals).into_iter().enumerate() {
            assert_eq!(ra.fetch(i as u64).unwrap(), e);
        }
    }

    #[test]
    fn max_prefix_works_too() {
        // combine need not be addition — running max is also a prefix op
        let t = tmpdir("prefix_max");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let vals = vec![3i64, 1, 4, 1, 5, 9, 2, 6];
        let ra = r.array::<i64>("a", 8, 0).unwrap();
        fill(&ra, &vals);
        parallel_prefix(&ra, |a, b| *a.max(b)).unwrap();
        let mut run = i64::MIN;
        for (i, v) in vals.iter().enumerate() {
            run = run.max(*v);
            assert_eq!(ra.fetch(i as u64).unwrap(), run);
        }
    }

    #[test]
    fn accel_scan_matches_log_rounds() {
        let t = tmpdir("prefix_accel");
        let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
        let vals: Vec<i64> = (0..997).map(|i| (i % 13) - 6).collect();
        let ra = r.array::<i64>("a", 997, 0).unwrap();
        fill(&ra, &vals);
        prefix_scan_array(&ra, &Accel::rust()).unwrap();
        for (i, e) in expect_prefix(&vals).into_iter().enumerate() {
            assert_eq!(ra.fetch(i as u64).unwrap(), e, "i={i}");
        }
    }

    #[test]
    fn prop_prefix_matches_serial() {
        prop_check("parallel prefix vs serial", 6, |rng| {
            let t = tmpdir("prefix_prop");
            let r = Roomy::open(crate::RoomyConfig::for_testing(t.path())).unwrap();
            let n = rng.range(1, 200) as u64;
            let vals: Vec<i64> = (0..n).map(|_| rng.range_i64(-50, 50)).collect();
            let ra = r.array::<i64>("a", n, 0).unwrap();
            fill(&ra, &vals);
            if rng.chance(0.5) {
                parallel_prefix(&ra, |a, b| a.wrapping_add(*b)).unwrap();
            } else {
                prefix_scan_array(&ra, &Accel::rust()).unwrap();
            }
            for (i, e) in expect_prefix(&vals).into_iter().enumerate() {
                assert_eq!(ra.fetch(i as u64).unwrap(), e);
            }
        });
    }
}
