//! Breadth-first search over implicit graphs (paper §3, final construct).
//!
//! The graph is defined by start elements and a neighbor-generating
//! function. Three drivers are provided:
//!
//! - [`bfs_list`] / [`bfs_list_batched`] — the paper's RoomyList
//!   pseudocode: generate the next level with `map`, dedupe within the
//!   level (`removeDupes`), subtract previous levels (`removeAll`), record
//!   (`addAll`), rotate;
//! - [`bfs_hash_batched`] — RoomyHashTable variant: state → level with
//!   insert-if-absent detection (no sorting; paper §2's bucketing
//!   argument);
//! - the RoomyBitArray variant lives with its application
//!   ([`crate::apps::pancake`]) since it needs a state-ranking function.
//!
//! Batched drivers collect the frontier into batches and call the
//! generator once per batch, which is how the XLA `bfs_expand` kernel is
//! driven.
//!
//! Determinism note: frontier batches are accumulated **per pool task**
//! ([`crate::roomy::RoomyList::map_batched`] builds them shard-locally),
//! so batch composition depends only on the frontier's on-disk shard
//! contents — never on `num_workers` or the schedule. Combined with the
//! pool's per-task delayed-op capture, both batched drivers stage their
//! neighbor ops in byte-identical order at any worker count, matching
//! the unbatched per-element idiom (one delayed op per neighbor from
//! inside `map`, as in the RoomyBitArray pancake variant).

use crate::error::Result;
use crate::roomy::{Element, Roomy};

/// Frontier batch size for the batched drivers (matches the AOT batch so
/// a full batch is one PJRT call).
pub const FRONTIER_BATCH: usize = 1024;

/// Per-level result of a BFS run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelStats {
    /// Number of states first reached at each level (level 0 = starts).
    pub levels: Vec<u64>,
    /// Total states reached.
    pub total: u64,
}

impl LevelStats {
    /// Eccentricity: index of the last non-empty level.
    pub fn depth(&self) -> u64 {
        self.levels.len() as u64 - 1
    }
}

/// Paper §3 BFS with a per-element neighbor generator.
pub fn bfs_list<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen: impl Fn(&T, &mut Vec<T>) + Sync,
) -> Result<LevelStats> {
    bfs_list_batched(r, prefix, starts, |batch, out| {
        let mut nbrs = Vec::new();
        for e in batch {
            gen(e, &mut nbrs);
            out.append(&mut nbrs);
        }
        Ok(())
    })
}

/// Paper §3 BFS (RoomyList variant) with a batched generator: `gen_batch`
/// receives a slice of frontier states and appends all their neighbors.
pub fn bfs_list_batched<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
) -> Result<LevelStats> {
    // Lists for all elements, current and next level (paper pseudocode).
    let all = r.list::<T>(&format!("{prefix}_all"))?;
    let mut cur = r.list::<T>(&format!("{prefix}_lev0"))?;
    for s in starts {
        all.add(s)?;
        cur.add(s)?;
    }
    all.sync()?;
    cur.sync()?;
    all.remove_dupes()?;
    cur.remove_dupes()?;

    let mut levels = vec![cur.size()];
    let mut lev = 0u32;
    // Generate levels until no new states are found.
    while cur.size() > 0 {
        lev += 1;
        let next = r.list::<T>(&format!("{prefix}_lev{lev}"))?;
        expand_into(&cur, &next, &gen_batch)?;
        next.sync()?;
        // Detect duplicates within the next level...
        next.remove_dupes()?;
        // ...and duplicates from previous levels.
        next.remove_all(&all)?;
        // Record new elements.
        all.add_all(&next)?;
        // Rotate levels.
        let name = cur.name().to_string();
        cur.destroy()?;
        r.release_name(&name);
        if next.size() > 0 {
            levels.push(next.size());
        }
        cur = next;
    }
    let name = cur.name().to_string();
    cur.destroy()?;
    r.release_name(&name);
    let total = all.size();
    let name = all.name().to_string();
    all.destroy()?;
    r.release_name(&name);
    Ok(LevelStats { levels, total })
}

/// RoomyHashTable BFS: `state → level`, duplicate detection by
/// insert-if-absent (bucketed, no external sorts).
pub fn bfs_hash_batched<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
) -> Result<LevelStats> {
    let table = r.hash_table::<T, u32>(&format!("{prefix}_levels"))?;
    let mut cur = r.list::<T>(&format!("{prefix}_lev0"))?;

    let mut lev = 0u32;
    for s in starts {
        table.insert(s, &0)?;
        cur.add(s)?;
    }
    table.sync()?;
    cur.sync()?;
    cur.remove_dupes()?;
    let mut levels = vec![table.size()];

    while cur.size() > 0 {
        lev += 1;
        let next = r.list::<T>(&format!("{prefix}_lev{lev}"))?;
        // visit: insert-if-absent; only first-time states emit to `next`
        // (duplicate detection is free — no sorting, paper §2's bucketing
        // argument).
        let next_emit = next.clone();
        let level_no = lev;
        let visit = table.register_update(move |k: &T, cur_v: Option<&u32>, _p: &()| {
            match cur_v {
                Some(&v) => Some(v), // already known: keep its level
                None => {
                    next_emit.add(k).expect("emit to next level");
                    Some(level_no)
                }
            }
        });
        // Batch-expand the frontier (per-task batches, so staging order
        // is schedule-independent); each neighbor becomes one delayed
        // table update.
        cur.map_batched(FRONTIER_BATCH, |batch| {
            let mut out = Vec::with_capacity(batch.len());
            gen_batch(batch, &mut out)?;
            for e in &out {
                table.update(e, &(), visit)?;
            }
            Ok(())
        })?;
        table.sync()?; // visit functions emit next-level adds
        next.sync()?;

        let name = cur.name().to_string();
        cur.destroy()?;
        r.release_name(&name);
        if next.size() > 0 {
            levels.push(next.size());
        }
        cur = next;
    }
    let name = cur.name().to_string();
    cur.destroy()?;
    r.release_name(&name);
    let total = table.size();
    let name = table.name().to_string();
    table.destroy()?;
    r.release_name(&name);
    Ok(LevelStats { levels, total })
}

/// Stream `cur` in per-task batches and stage every generated neighbor as
/// a delayed `next.add` (byte-deterministic: batch composition is
/// shard-local and the staged adds ride the pool's per-task op capture).
fn expand_into<T: Element>(
    cur: &crate::roomy::RoomyList<T>,
    next: &crate::roomy::RoomyList<T>,
    gen_batch: &(impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync),
) -> Result<()> {
    cur.map_batched(FRONTIER_BATCH, |batch| {
        let mut out = Vec::with_capacity(batch.len());
        gen_batch(batch, &mut out)?;
        for e in &out {
            next.add(e)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        crate::Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    /// Implicit path graph 0-1-2-...-(m-1): BFS from 0 has m levels of 1.
    #[test]
    fn path_graph_list() {
        let t = tmpdir("bfs_path");
        let r = mk(t.path());
        let m = 10u64;
        let stats = bfs_list(&r, "path", &[0u64], |&v, out| {
            if v + 1 < m {
                out.push(v + 1);
            }
            if v > 0 {
                out.push(v - 1);
            }
        })
        .unwrap();
        assert_eq!(stats.levels, vec![1; m as usize]);
        assert_eq!(stats.total, m);
        assert_eq!(stats.depth(), m - 1);
    }

    /// Hypercube {0,1}^d: level k has C(d, k) states.
    #[test]
    fn hypercube_list() {
        let t = tmpdir("bfs_cube");
        let r = mk(t.path());
        let d = 8u32;
        let stats = bfs_list(&r, "cube", &[0u64], |&v, out| {
            for b in 0..d {
                out.push(v ^ (1 << b));
            }
        })
        .unwrap();
        let binom: Vec<u64> = (0..=d as u64).scan(1u64, |c, k| {
            let out = *c;
            *c = *c * (d as u64 - k) / (k + 1);
            Some(out)
        })
        .collect();
        assert_eq!(stats.levels, binom);
        assert_eq!(stats.total, 1 << d);
    }

    #[test]
    fn hypercube_hash_matches_list() {
        let t = tmpdir("bfs_cube_hash");
        let r = mk(t.path());
        let d = 6u32;
        let gen = |batch: &[u64], out: &mut Vec<u64>| {
            for &v in batch {
                for b in 0..d {
                    out.push(v ^ (1 << b));
                }
            }
            Ok(())
        };
        let stats = bfs_hash_batched(&r, "cubeh", &[0u64], gen).unwrap();
        let binom: Vec<u64> = (0..=d as u64).scan(1u64, |c, k| {
            let out = *c;
            *c = *c * (d as u64 - k) / (k + 1);
            Some(out)
        })
        .collect();
        assert_eq!(stats.levels, binom);
        assert_eq!(stats.total, 1 << d);
    }

    #[test]
    fn disconnected_graph_stops() {
        let t = tmpdir("bfs_disc");
        let r = mk(t.path());
        let stats = bfs_list(&r, "disc", &[5u64], |&v, out| {
            out.push(v); // only self-loop
        })
        .unwrap();
        assert_eq!(stats.levels, vec![1]);
        assert_eq!(stats.total, 1);
    }

    #[test]
    fn multiple_starts_deduped() {
        let t = tmpdir("bfs_multi");
        let r = mk(t.path());
        let stats = bfs_list(&r, "multi", &[0u64, 0u64, 4u64], |&v, out| {
            if v < 4 {
                out.push(v + 1);
            }
        })
        .unwrap();
        // starts {0,4}; 0→1→2→3→4(dup)
        assert_eq!(stats.total, 5);
        assert_eq!(stats.levels[0], 2);
    }
}
