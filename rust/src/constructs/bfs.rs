//! Breadth-first search over implicit graphs (paper §3, final construct).
//!
//! The graph is defined by start elements and a neighbor-generating
//! function. Three drivers are provided:
//!
//! - [`bfs_list`] / [`bfs_list_batched`] — the paper's RoomyList
//!   pseudocode: generate the next level with `map`, dedupe within the
//!   level (`removeDupes`), subtract previous levels (`removeAll`), record
//!   (`addAll`), rotate;
//! - [`bfs_hash_batched`] — RoomyHashTable variant: state → level with
//!   insert-if-absent detection (no sorting; paper §2's bucketing
//!   argument);
//! - the RoomyBitArray variant lives with its application
//!   ([`crate::apps::pancake`]) since it needs a state-ranking function.
//!
//! Batched drivers collect the frontier into batches and call the
//! generator once per batch, which is how the XLA `bfs_expand` kernel is
//! driven.
//!
//! Determinism note: frontier batches are accumulated **per pool task**
//! ([`crate::roomy::RoomyList::map_batched`] builds them shard-locally),
//! so batch composition depends only on the frontier's on-disk shard
//! contents — never on `num_workers`, the pool's steal policy, or the
//! schedule. Combined with the pool's per-task delayed-op capture, both
//! batched drivers stage their neighbor ops in byte-identical order at
//! any worker count, matching the unbatched per-element idiom (one
//! delayed op per neighbor from inside `map`, as in the RoomyBitArray
//! pancake variant).
//!
//! Scheduling note: the frontier scans ride the locality-aware pool
//! directly — `map_batched` submits one task per frontier shard tagged
//! with its owning node and hinted with its shard file, so while shard
//! `s` expands, the same node's read lane is already staging shard
//! `s+1`'s first chunk (cross-task prefetch,
//! [`crate::storage::pipeline`]), and under `ROOMY_STEAL=off` every
//! shard expands strictly on its home worker.

use crate::error::{Result, RoomyError};
use crate::roomy::{Element, Roomy};
use crate::storage::checkpoint::{CheckpointManager, Checkpointable, Manifest};

/// Frontier batch size for the batched drivers (matches the AOT batch so
/// a full batch is one PJRT call).
pub const FRONTIER_BATCH: usize = 1024;

/// Per-level result of a BFS run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelStats {
    /// Number of states first reached at each level (level 0 = starts).
    pub levels: Vec<u64>,
    /// Total states reached.
    pub total: u64,
}

impl LevelStats {
    /// Eccentricity: index of the last non-empty level.
    pub fn depth(&self) -> u64 {
        self.levels.len() as u64 - 1
    }
}

/// Paper §3 BFS with a per-element neighbor generator.
pub fn bfs_list<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen: impl Fn(&T, &mut Vec<T>) + Sync,
) -> Result<LevelStats> {
    bfs_list_batched(r, prefix, starts, |batch, out| {
        let mut nbrs = Vec::new();
        for e in batch {
            gen(e, &mut nbrs);
            out.append(&mut nbrs);
        }
        Ok(())
    })
}

/// Paper §3 BFS (RoomyList variant) with a batched generator: `gen_batch`
/// receives a slice of frontier states and appends all their neighbors.
pub fn bfs_list_batched<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
) -> Result<LevelStats> {
    match bfs_list_impl(r, prefix, starts, gen_batch, None)? {
        BfsOutcome::Complete(stats) => Ok(stats),
        BfsOutcome::Suspended { .. } => unreachable!("no checkpoint hook without options"),
    }
}

/// RoomyHashTable BFS: `state → level`, duplicate detection by
/// insert-if-absent (bucketed, no external sorts).
pub fn bfs_hash_batched<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
) -> Result<LevelStats> {
    match bfs_hash_impl(r, prefix, starts, gen_batch, None)? {
        BfsOutcome::Complete(stats) => Ok(stats),
        BfsOutcome::Suspended { .. } => unreachable!("no checkpoint hook without options"),
    }
}

// ---------------------------------------------------------------------
// Resumable drivers (durable checkpoint per level)
// ---------------------------------------------------------------------

/// Options for the resumable BFS drivers.
pub struct ResumableBfs<'a> {
    /// Where checkpoints are saved/restored.
    pub manager: &'a CheckpointManager,
    /// Checkpoint name for this run (one BFS per tag).
    pub tag: String,
    /// Testing/abort hook simulating a kill: suspend (checkpoint intact,
    /// in-RAM state abandoned) after completing this many levels *in this
    /// invocation*. `None` runs to completion.
    pub stop_after_levels: Option<u32>,
}

impl<'a> ResumableBfs<'a> {
    /// Run-to-completion options under checkpoint `tag`.
    pub fn new(manager: &'a CheckpointManager, tag: impl Into<String>) -> Self {
        ResumableBfs { manager, tag: tag.into(), stop_after_levels: None }
    }
}

/// Result of a resumable BFS invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BfsOutcome {
    /// The search ran to the end; a final checkpoint (app key `done`)
    /// pins the complete reachable set.
    Complete(LevelStats),
    /// Suspended by [`ResumableBfs::stop_after_levels`]; the checkpoint
    /// holds everything needed to continue from `next_level` (call the
    /// same driver again, typically from a fresh session).
    Suspended { next_level: u32 },
}

fn fmt_levels(levels: &[u64]) -> String {
    levels.iter().map(u64::to_string).collect::<Vec<_>>().join(",")
}

pub(crate) fn app_str<'m>(m: &'m Manifest, key: &str) -> Result<&'m str> {
    m.app(key)
        .ok_or_else(|| RoomyError::Checkpoint(format!("BFS checkpoint missing app key {key:?}")))
}

pub(crate) fn app_u64(m: &Manifest, key: &str) -> Result<u64> {
    app_str(m, key)?
        .parse()
        .map_err(|_| RoomyError::Checkpoint(format!("BFS checkpoint app key {key:?} is not a number")))
}

pub(crate) fn app_levels(m: &Manifest) -> Result<Vec<u64>> {
    app_str(m, "levels")?
        .split(',')
        .map(|v| {
            v.parse::<u64>().map_err(|_| {
                RoomyError::Checkpoint("BFS checkpoint level profile is corrupted".into())
            })
        })
        .collect()
}

/// Checkpoint one completed level: the snapshotted structures plus the
/// driver state (level counter + profile) as app rows.
pub(crate) fn save_level(
    opts: &ResumableBfs<'_>,
    structs: &[&dyn Checkpointable],
    lev: u32,
    levels: &[u64],
) -> Result<()> {
    let lev_s = lev.to_string();
    let levels_s = fmt_levels(levels);
    opts.manager
        .save(&opts.tag, structs, &[("lev", &lev_s), ("levels", &levels_s)])?;
    Ok(())
}

/// Checkpoint the final state (`done` flag + totals) so a re-invocation
/// returns the finished stats and the tests can digest the result bytes.
pub(crate) fn save_final(
    opts: &ResumableBfs<'_>,
    structs: &[&dyn Checkpointable],
    lev: u32,
    levels: &[u64],
    total: u64,
) -> Result<()> {
    let lev_s = lev.to_string();
    let levels_s = fmt_levels(levels);
    let total_s = total.to_string();
    opts.manager.save(
        &opts.tag,
        structs,
        &[("done", "1"), ("lev", &lev_s), ("levels", &levels_s), ("total", &total_s)],
    )?;
    Ok(())
}

/// [`bfs_list_batched`] with a durable checkpoint after every level:
/// frontier + all-list + level profile are snapshotted atomically, so a
/// run killed between levels resumes from level *k* — and produces
/// byte-identical final state and level profile to an uninterrupted run
/// (pinned in `tests/integration_resume.rs` across worker counts and
/// pipeline depths). Invoke with the same `prefix`/`tag` to resume; an
/// already-finished checkpoint returns its stats immediately.
pub fn bfs_list_resumable<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
    opts: &ResumableBfs<'_>,
) -> Result<BfsOutcome> {
    bfs_list_impl(r, prefix, starts, gen_batch, Some(opts))
}

/// [`bfs_hash_batched`] with a durable checkpoint after every level (see
/// [`bfs_list_resumable`]): level table + frontier are snapshotted
/// atomically at each level boundary.
pub fn bfs_hash_resumable<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
    opts: &ResumableBfs<'_>,
) -> Result<BfsOutcome> {
    bfs_hash_impl(r, prefix, starts, gen_batch, Some(opts))
}

/// Whether a checkpointed driver invocation should suspend now — the
/// simulated kill of [`ResumableBfs::stop_after_levels`]. The caller
/// releases the structure names and abandons the in-RAM state; the
/// committed checkpoint is the only thing a resume reads.
pub(crate) fn should_suspend(ckpt: Option<&ResumableBfs<'_>>, completed_here: u32) -> bool {
    ckpt.is_some_and(|o| o.stop_after_levels.is_some_and(|k| completed_here >= k))
}

/// The one RoomyList BFS loop both [`bfs_list_batched`] (ckpt = None) and
/// [`bfs_list_resumable`] run — a single body so the plain and resumable
/// drivers can never drift apart in the bytes they produce.
fn bfs_list_impl<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
    ckpt: Option<&ResumableBfs<'_>>,
) -> Result<BfsOutcome> {
    let all_name = format!("{prefix}_all");

    // Resume from a checkpoint when one exists; a finished checkpoint
    // answers from its manifest alone (no files are copied back).
    let mut resumed = None;
    if let Some(opts) = ckpt {
        if opts.manager.exists(&opts.tag) {
            let m = opts.manager.load_manifest(&opts.tag)?;
            let levels = app_levels(&m)?;
            let lev = app_u64(&m, "lev")? as u32;
            if m.app("done") == Some("1") {
                let total = app_u64(&m, "total")?;
                return Ok(BfsOutcome::Complete(LevelStats { levels, total }));
            }
            let res = opts.manager.restore(&opts.tag)?;
            let all = r.restored_list::<T>(&res, &all_name)?;
            let cur = r.restored_list::<T>(&res, &format!("{prefix}_lev{lev}"))?;
            resumed = Some((all, cur, levels, lev));
        }
    }
    let (all, mut cur, mut levels, mut lev) = match resumed {
        Some(state) => state,
        None => {
            // Lists for all elements, current and next level (paper
            // pseudocode).
            let all = r.list::<T>(&all_name)?;
            let cur = r.list::<T>(&format!("{prefix}_lev0"))?;
            for s in starts {
                all.add(s)?;
                cur.add(s)?;
            }
            all.sync()?;
            cur.sync()?;
            all.remove_dupes()?;
            cur.remove_dupes()?;
            let levels = vec![cur.size()];
            if let Some(opts) = ckpt {
                save_level(opts, &[&all as &dyn Checkpointable, &cur], 0, &levels)?;
            }
            (all, cur, levels, 0u32)
        }
    };

    let mut completed_here = 0u32;
    // Generate levels until no new states are found.
    while cur.size() > 0 {
        if should_suspend(ckpt, completed_here) {
            r.release_name(all.name());
            r.release_name(cur.name());
            return Ok(BfsOutcome::Suspended { next_level: lev + 1 });
        }
        lev += 1;
        let mut lsp =
            crate::obs::trace::span(crate::obs::trace::Kind::Level, "bfs.level", None);
        lsp.set_args(lev as u64, cur.size());
        let next = r.list::<T>(&format!("{prefix}_lev{lev}"))?;
        expand_into(&cur, &next, &gen_batch)?;
        next.sync()?;
        // Detect duplicates within the next level...
        next.remove_dupes()?;
        // ...and duplicates from previous levels.
        next.remove_all(&all)?;
        // Record new elements.
        all.add_all(&next)?;
        // Rotate levels.
        let name = cur.name().to_string();
        cur.destroy()?;
        r.release_name(&name);
        if next.size() > 0 {
            levels.push(next.size());
        }
        cur = next;
        drop(lsp);
        if let Some(opts) = ckpt {
            save_level(opts, &[&all as &dyn Checkpointable, &cur], lev, &levels)?;
        }
        completed_here += 1;
    }
    let name = cur.name().to_string();
    cur.destroy()?;
    r.release_name(&name);
    let total = all.size();
    if let Some(opts) = ckpt {
        save_final(opts, &[&all as &dyn Checkpointable], lev, &levels, total)?;
    }
    let name = all.name().to_string();
    all.destroy()?;
    r.release_name(&name);
    Ok(BfsOutcome::Complete(LevelStats { levels, total }))
}

/// The one RoomyHashTable BFS loop both [`bfs_hash_batched`] (ckpt =
/// None) and [`bfs_hash_resumable`] run.
fn bfs_hash_impl<T: Element>(
    r: &Roomy,
    prefix: &str,
    starts: &[T],
    gen_batch: impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync,
    ckpt: Option<&ResumableBfs<'_>>,
) -> Result<BfsOutcome> {
    let table_name = format!("{prefix}_levels");

    let mut resumed = None;
    if let Some(opts) = ckpt {
        if opts.manager.exists(&opts.tag) {
            let m = opts.manager.load_manifest(&opts.tag)?;
            let levels = app_levels(&m)?;
            let lev = app_u64(&m, "lev")? as u32;
            if m.app("done") == Some("1") {
                let total = app_u64(&m, "total")?;
                return Ok(BfsOutcome::Complete(LevelStats { levels, total }));
            }
            let res = opts.manager.restore(&opts.tag)?;
            let table = r.restored_hash_table::<T, u32>(&res, &table_name)?;
            let cur = r.restored_list::<T>(&res, &format!("{prefix}_lev{lev}"))?;
            resumed = Some((table, cur, levels, lev));
        }
    }
    let (table, mut cur, mut levels, mut lev) = match resumed {
        Some(state) => state,
        None => {
            let table = r.hash_table::<T, u32>(&table_name)?;
            let cur = r.list::<T>(&format!("{prefix}_lev0"))?;
            for s in starts {
                table.insert(s, &0)?;
                cur.add(s)?;
            }
            table.sync()?;
            cur.sync()?;
            cur.remove_dupes()?;
            let levels = vec![table.size()];
            if let Some(opts) = ckpt {
                save_level(opts, &[&table as &dyn Checkpointable, &cur], 0, &levels)?;
            }
            (table, cur, levels, 0u32)
        }
    };

    let mut completed_here = 0u32;
    while cur.size() > 0 {
        if should_suspend(ckpt, completed_here) {
            r.release_name(table.name());
            r.release_name(cur.name());
            return Ok(BfsOutcome::Suspended { next_level: lev + 1 });
        }
        lev += 1;
        let mut lsp =
            crate::obs::trace::span(crate::obs::trace::Kind::Level, "bfs.level", None);
        lsp.set_args(lev as u64, cur.size());
        let next = r.list::<T>(&format!("{prefix}_lev{lev}"))?;
        // visit: insert-if-absent; only first-time states emit to `next`
        // (duplicate detection is free — no sorting, paper §2's bucketing
        // argument). Registered function ids restart per session, but ids
        // only live inside a level's staged ops — never in checkpointed
        // bytes.
        let next_emit = next.clone();
        let level_no = lev;
        let visit = table.register_update(move |k: &T, cur_v: Option<&u32>, _p: &()| {
            match cur_v {
                Some(&v) => Some(v), // already known: keep its level
                None => {
                    next_emit.add(k).expect("emit to next level");
                    Some(level_no)
                }
            }
        });
        // Batch-expand the frontier (per-task batches, so staging order
        // is schedule-independent); each neighbor becomes one delayed
        // table update.
        cur.map_batched(FRONTIER_BATCH, |batch| {
            let mut out = Vec::with_capacity(batch.len());
            gen_batch(batch, &mut out)?;
            for e in &out {
                table.update(e, &(), visit)?;
            }
            Ok(())
        })?;
        table.sync()?; // visit functions emit next-level adds
        next.sync()?;

        let name = cur.name().to_string();
        cur.destroy()?;
        r.release_name(&name);
        if next.size() > 0 {
            levels.push(next.size());
        }
        cur = next;
        drop(lsp);
        if let Some(opts) = ckpt {
            save_level(opts, &[&table as &dyn Checkpointable, &cur], lev, &levels)?;
        }
        completed_here += 1;
    }
    let name = cur.name().to_string();
    cur.destroy()?;
    r.release_name(&name);
    let total = table.size();
    if let Some(opts) = ckpt {
        save_final(opts, &[&table as &dyn Checkpointable], lev, &levels, total)?;
    }
    let name = table.name().to_string();
    table.destroy()?;
    r.release_name(&name);
    Ok(BfsOutcome::Complete(LevelStats { levels, total }))
}

/// Stream `cur` in per-task batches and stage every generated neighbor as
/// a delayed `next.add` (byte-deterministic: batch composition is
/// shard-local and the staged adds ride the pool's per-task op capture).
fn expand_into<T: Element>(
    cur: &crate::roomy::RoomyList<T>,
    next: &crate::roomy::RoomyList<T>,
    gen_batch: &(impl Fn(&[T], &mut Vec<T>) -> Result<()> + Sync),
) -> Result<()> {
    cur.map_batched(FRONTIER_BATCH, |batch| {
        let mut out = Vec::with_capacity(batch.len());
        gen_batch(batch, &mut out)?;
        for e in &out {
            next.add(e)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::tmpdir;

    fn mk(root: &std::path::Path) -> Roomy {
        crate::Roomy::open(crate::RoomyConfig::for_testing(root)).unwrap()
    }

    /// Implicit path graph 0-1-2-...-(m-1): BFS from 0 has m levels of 1.
    #[test]
    fn path_graph_list() {
        let t = tmpdir("bfs_path");
        let r = mk(t.path());
        let m = 10u64;
        let stats = bfs_list(&r, "path", &[0u64], |&v, out| {
            if v + 1 < m {
                out.push(v + 1);
            }
            if v > 0 {
                out.push(v - 1);
            }
        })
        .unwrap();
        assert_eq!(stats.levels, vec![1; m as usize]);
        assert_eq!(stats.total, m);
        assert_eq!(stats.depth(), m - 1);
    }

    /// Hypercube {0,1}^d: level k has C(d, k) states.
    #[test]
    fn hypercube_list() {
        let t = tmpdir("bfs_cube");
        let r = mk(t.path());
        let d = 8u32;
        let stats = bfs_list(&r, "cube", &[0u64], |&v, out| {
            for b in 0..d {
                out.push(v ^ (1 << b));
            }
        })
        .unwrap();
        let binom: Vec<u64> = (0..=d as u64).scan(1u64, |c, k| {
            let out = *c;
            *c = *c * (d as u64 - k) / (k + 1);
            Some(out)
        })
        .collect();
        assert_eq!(stats.levels, binom);
        assert_eq!(stats.total, 1 << d);
    }

    #[test]
    fn hypercube_hash_matches_list() {
        let t = tmpdir("bfs_cube_hash");
        let r = mk(t.path());
        let d = 6u32;
        let gen = |batch: &[u64], out: &mut Vec<u64>| {
            for &v in batch {
                for b in 0..d {
                    out.push(v ^ (1 << b));
                }
            }
            Ok(())
        };
        let stats = bfs_hash_batched(&r, "cubeh", &[0u64], gen).unwrap();
        let binom: Vec<u64> = (0..=d as u64).scan(1u64, |c, k| {
            let out = *c;
            *c = *c * (d as u64 - k) / (k + 1);
            Some(out)
        })
        .collect();
        assert_eq!(stats.levels, binom);
        assert_eq!(stats.total, 1 << d);
    }

    fn cube_gen(d: u32) -> impl Fn(&[u64], &mut Vec<u64>) -> Result<()> + Sync {
        move |batch, out| {
            for &v in batch {
                for b in 0..d {
                    out.push(v ^ (1 << b));
                }
            }
            Ok(())
        }
    }

    #[test]
    fn resumable_list_uninterrupted_matches_plain_driver() {
        let t = tmpdir("bfs_res_plain");
        let r = mk(t.path());
        let mgr = r.checkpoints().unwrap();
        let out = bfs_list_resumable(&r, "cube", &[0u64], cube_gen(7), &ResumableBfs::new(&mgr, "cube"))
            .unwrap();
        let t2 = tmpdir("bfs_res_plain_ref");
        let r2 = mk(t2.path());
        let reference = bfs_list_batched(&r2, "cube", &[0u64], cube_gen(7)).unwrap();
        assert_eq!(out, BfsOutcome::Complete(reference));
        // invoking again returns the finished stats straight from the
        // final checkpoint
        let again =
            bfs_list_resumable(&r, "cube", &[0u64], cube_gen(7), &ResumableBfs::new(&mgr, "cube"))
                .unwrap();
        assert_eq!(again, out);
    }

    #[test]
    fn resumable_list_kill_and_resume_in_fresh_session() {
        let reference = {
            let t = tmpdir("bfs_res_kill_ref");
            let r = mk(t.path());
            bfs_list_batched(&r, "cube", &[0u64], cube_gen(8)).unwrap()
        };
        let t = tmpdir("bfs_res_kill");
        // session 1: killed after 3 levels
        {
            let r = mk(t.path());
            let mgr = r.checkpoints().unwrap();
            let opts = ResumableBfs {
                manager: &mgr,
                tag: "cube".into(),
                stop_after_levels: Some(3),
            };
            let out = bfs_list_resumable(&r, "cube", &[0u64], cube_gen(8), &opts).unwrap();
            assert_eq!(out, BfsOutcome::Suspended { next_level: 4 });
        }
        // session 2: fresh process over the same root resumes to the end
        let r = mk(t.path());
        let mgr = r.checkpoints().unwrap();
        let out =
            bfs_list_resumable(&r, "cube", &[0u64], cube_gen(8), &ResumableBfs::new(&mgr, "cube"))
                .unwrap();
        assert_eq!(out, BfsOutcome::Complete(reference));
    }

    #[test]
    fn resumable_hash_kill_and_resume() {
        let reference = {
            let t = tmpdir("bfs_resh_ref");
            let r = mk(t.path());
            bfs_hash_batched(&r, "cube", &[0u64], cube_gen(7)).unwrap()
        };
        let t = tmpdir("bfs_resh");
        {
            let r = mk(t.path());
            let mgr = r.checkpoints().unwrap();
            let opts = ResumableBfs {
                manager: &mgr,
                tag: "cubeh".into(),
                stop_after_levels: Some(2),
            };
            let out = bfs_hash_resumable(&r, "cube", &[0u64], cube_gen(7), &opts).unwrap();
            assert_eq!(out, BfsOutcome::Suspended { next_level: 3 });
        }
        let r = mk(t.path());
        let mgr = r.checkpoints().unwrap();
        let out =
            bfs_hash_resumable(&r, "cube", &[0u64], cube_gen(7), &ResumableBfs::new(&mgr, "cubeh"))
                .unwrap();
        assert_eq!(out, BfsOutcome::Complete(reference));
    }

    #[test]
    fn disconnected_graph_stops() {
        let t = tmpdir("bfs_disc");
        let r = mk(t.path());
        let stats = bfs_list(&r, "disc", &[5u64], |&v, out| {
            out.push(v); // only self-loop
        })
        .unwrap();
        assert_eq!(stats.levels, vec![1]);
        assert_eq!(stats.total, 1);
    }

    #[test]
    fn multiple_starts_deduped() {
        let t = tmpdir("bfs_multi");
        let r = mk(t.path());
        let stats = bfs_list(&r, "multi", &[0u64, 0u64, 4u64], |&v, out| {
            if v < 4 {
                out.push(v + 1);
            }
        })
        .unwrap();
        // starts {0,4}; 0→1→2→3→4(dup)
        assert_eq!(stats.total, 5);
        assert_eq!(stats.levels[0], 2);
    }
}
