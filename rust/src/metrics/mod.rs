//! Metrics: per-disk I/O statistics and phase timing.
//!
//! Roomy's performance story is entirely about *which bytes stream when*,
//! so every disk touch in [`crate::storage`] is counted here. The
//! experiment harnesses (rust/benches) read these counters to report
//! aggregate bandwidth, seek counts, and sync-phase breakdowns.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Atomic I/O counters for one simulated node disk (or an aggregate).
#[derive(Debug, Default)]
pub struct IoStats {
    /// Payload bytes read from disk files.
    pub bytes_read: AtomicU64,
    /// Payload bytes written to disk files.
    pub bytes_written: AtomicU64,
    /// Read calls issued.
    pub reads: AtomicU64,
    /// Write calls issued.
    pub writes: AtomicU64,
    /// File opens + explicit repositions — the unit the seek penalty is
    /// charged against.
    pub seeks: AtomicU64,
    /// Nanoseconds spent sleeping to enforce the simulated [`crate::DiskPolicy`].
    pub throttle_ns: AtomicU64,
}

impl IoStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_read(&self, bytes: u64) {
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_write(&self, bytes: u64) {
        self.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.writes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_seek(&self) {
        self.seeks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_throttle(&self, d: Duration) {
        self.throttle_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            reads: self.reads.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            seeks: self.seeks.load(Ordering::Relaxed),
            throttle_ns: self.throttle_ns.load(Ordering::Relaxed),
        }
    }

    /// Zero all counters (benchmark harness support).
    pub fn reset(&self) {
        self.bytes_read.store(0, Ordering::Relaxed);
        self.bytes_written.store(0, Ordering::Relaxed);
        self.reads.store(0, Ordering::Relaxed);
        self.writes.store(0, Ordering::Relaxed);
        self.seeks.store(0, Ordering::Relaxed);
        self.throttle_ns.store(0, Ordering::Relaxed);
    }
}

/// Plain-data snapshot of [`IoStats`]; supports aggregation and deltas.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoSnapshot {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub reads: u64,
    pub writes: u64,
    pub seeks: u64,
    pub throttle_ns: u64,
}

impl IoSnapshot {
    /// Total payload bytes moved (read + written).
    pub fn bytes_total(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Counter-wise difference `self - earlier` (saturating).
    pub fn delta(&self, earlier: &IoSnapshot) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            bytes_written: self.bytes_written.saturating_sub(earlier.bytes_written),
            reads: self.reads.saturating_sub(earlier.reads),
            writes: self.writes.saturating_sub(earlier.writes),
            seeks: self.seeks.saturating_sub(earlier.seeks),
            throttle_ns: self.throttle_ns.saturating_sub(earlier.throttle_ns),
        }
    }
}

impl std::ops::Add for IoSnapshot {
    type Output = IoSnapshot;
    fn add(self, o: IoSnapshot) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.bytes_read + o.bytes_read,
            bytes_written: self.bytes_written + o.bytes_written,
            reads: self.reads + o.reads,
            writes: self.writes + o.writes,
            seeks: self.seeks + o.seeks,
            throttle_ns: self.throttle_ns + o.throttle_ns,
        }
    }
}

/// Named wall-clock phase accumulator (sync shuffle, sort, apply, ...).
///
/// Cheap enough for per-sync use; read by benches for the E4 "time
/// breakdown" rows.
#[derive(Debug, Default)]
pub struct PhaseTimes {
    inner: Mutex<Vec<(String, Duration, u64)>>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (creating it on first use).
    pub fn add(&self, name: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        if let Some(row) = g.iter_mut().find(|r| r.0 == name) {
            row.1 += d;
            row.2 += 1;
        } else {
            g.push((name.to_string(), d, 1));
        }
    }

    /// Time the closure and charge it to `name`.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(name, t0.elapsed());
        r
    }

    /// (phase, total duration, hits) rows in insertion order.
    pub fn rows(&self) -> Vec<(String, Duration, u64)> {
        self.inner.lock().unwrap().clone()
    }

    /// Total duration recorded for `name`, if any.
    pub fn get(&self, name: &str) -> Option<Duration> {
        self.inner.lock().unwrap().iter().find(|r| r.0 == name).map(|r| r.1)
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }

    /// Human-readable multi-line report.
    pub fn report(&self) -> String {
        let rows = self.rows();
        let mut s = String::new();
        for (name, d, hits) in rows {
            s.push_str(&format!("  {name:<28} {:>10.3} ms  ({hits} calls)\n", d.as_secs_f64() * 1e3));
        }
        s
    }
}

/// Counters for one node's overlapped-I/O pipeline
/// ([`crate::storage::pipeline`]): read-ahead / write-behind volume, how
/// long consumers stalled waiting on the service lanes, and the largest
/// buffer RAM any single stream allocated (the observable form of the
/// pipeline's `depth × chunk` space bound).
#[derive(Debug, Default)]
pub struct PipelineStats {
    /// Streams opened in overlapped mode (depth > 0).
    streams: AtomicU64,
    /// Chunks delivered ahead of the consumer by the read lane.
    chunks_ahead: AtomicU64,
    /// Bytes read ahead by the read lane.
    bytes_ahead: AtomicU64,
    /// Chunks flushed behind the producer by the write lane.
    chunks_behind: AtomicU64,
    /// Bytes written behind by the write lane.
    bytes_behind: AtomicU64,
    /// Nanoseconds consumers spent blocked waiting for a prefetched chunk
    /// (read-ahead misses; 0 = the pipeline always stayed ahead).
    reader_wait_ns: AtomicU64,
    /// Nanoseconds producers spent blocked waiting for a free buffer
    /// (write-behind backpressure).
    writer_wait_ns: AtomicU64,
    /// Largest buffer RAM a single stream ever allocated — must stay
    /// ≤ depth × chunk (tests assert this).
    peak_stream_buf: AtomicU64,
    /// Cross-task prefetch hints accepted by the hint cache (posted to
    /// the read lane). Dropped hints (cache full, duplicate path) are
    /// not counted anywhere — they cost nothing.
    hints_posted: AtomicU64,
    /// Hints whose warmed first chunk a scan adopted (the scan skipped
    /// its own open + first-chunk read).
    hint_hits: AtomicU64,
    /// Hints that did work nobody used: the warm failed, went stale
    /// (file replaced/grown before the scan arrived), or was still
    /// unconsumed at teardown. Eventually `posted == hits + wastes`.
    hint_wastes: AtomicU64,
}

impl PipelineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_stream(&self) {
        self.streams.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_read_ahead(&self, bytes: u64) {
        self.chunks_ahead.fetch_add(1, Ordering::Relaxed);
        self.bytes_ahead.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_write_behind(&self, bytes: u64) {
        self.chunks_behind.fetch_add(1, Ordering::Relaxed);
        self.bytes_behind.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_reader_wait(&self, d: Duration) {
        self.reader_wait_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_writer_wait(&self, d: Duration) {
        self.writer_wait_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Fold one stream's current buffer allocation into the high-water
    /// mark.
    pub fn note_stream_buf(&self, bytes: u64) {
        self.peak_stream_buf.fetch_max(bytes, Ordering::Relaxed);
    }

    pub fn add_hint_posted(&self) {
        self.hints_posted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_hint_hit(&self) {
        self.hint_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_hint_wastes(&self, n: u64) {
        self.hint_wastes.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> PipelineSnapshot {
        PipelineSnapshot {
            streams: self.streams.load(Ordering::Relaxed),
            chunks_ahead: self.chunks_ahead.load(Ordering::Relaxed),
            bytes_ahead: self.bytes_ahead.load(Ordering::Relaxed),
            chunks_behind: self.chunks_behind.load(Ordering::Relaxed),
            bytes_behind: self.bytes_behind.load(Ordering::Relaxed),
            reader_wait_ns: self.reader_wait_ns.load(Ordering::Relaxed),
            writer_wait_ns: self.writer_wait_ns.load(Ordering::Relaxed),
            peak_stream_buf: self.peak_stream_buf.load(Ordering::Relaxed),
            hints_posted: self.hints_posted.load(Ordering::Relaxed),
            hint_hits: self.hint_hits.load(Ordering::Relaxed),
            hint_wastes: self.hint_wastes.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.streams.store(0, Ordering::Relaxed);
        self.chunks_ahead.store(0, Ordering::Relaxed);
        self.bytes_ahead.store(0, Ordering::Relaxed);
        self.chunks_behind.store(0, Ordering::Relaxed);
        self.bytes_behind.store(0, Ordering::Relaxed);
        self.reader_wait_ns.store(0, Ordering::Relaxed);
        self.writer_wait_ns.store(0, Ordering::Relaxed);
        self.peak_stream_buf.store(0, Ordering::Relaxed);
        self.hints_posted.store(0, Ordering::Relaxed);
        self.hint_hits.store(0, Ordering::Relaxed);
        self.hint_wastes.store(0, Ordering::Relaxed);
    }
}

/// Plain-data snapshot of [`PipelineStats`]; `+` aggregates nodes
/// (peak is a max, everything else sums).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipelineSnapshot {
    pub streams: u64,
    pub chunks_ahead: u64,
    pub bytes_ahead: u64,
    pub chunks_behind: u64,
    pub bytes_behind: u64,
    pub reader_wait_ns: u64,
    pub writer_wait_ns: u64,
    pub peak_stream_buf: u64,
    pub hints_posted: u64,
    pub hint_hits: u64,
    pub hint_wastes: u64,
}

impl PipelineSnapshot {
    /// Fraction of posted prefetch hints a scan actually adopted
    /// (0.0 when none were posted).
    pub fn hint_hit_rate(&self) -> f64 {
        if self.hints_posted == 0 {
            0.0
        } else {
            self.hint_hits as f64 / self.hints_posted as f64
        }
    }
}

impl std::ops::Add for PipelineSnapshot {
    type Output = PipelineSnapshot;
    fn add(self, o: PipelineSnapshot) -> PipelineSnapshot {
        PipelineSnapshot {
            streams: self.streams + o.streams,
            chunks_ahead: self.chunks_ahead + o.chunks_ahead,
            bytes_ahead: self.bytes_ahead + o.bytes_ahead,
            chunks_behind: self.chunks_behind + o.chunks_behind,
            bytes_behind: self.bytes_behind + o.bytes_behind,
            reader_wait_ns: self.reader_wait_ns + o.reader_wait_ns,
            writer_wait_ns: self.writer_wait_ns + o.writer_wait_ns,
            peak_stream_buf: self.peak_stream_buf.max(o.peak_stream_buf),
            hints_posted: self.hints_posted + o.hints_posted,
            hint_hits: self.hint_hits + o.hint_hits,
            hint_wastes: self.hint_wastes + o.hint_wastes,
        }
    }
}

/// Counters for the durable-checkpoint subsystem
/// ([`crate::storage::checkpoint`]): how many snapshots were saved and
/// restored, how many bucket files each path hardlinked vs copied, the
/// payload bytes involved, and the wall time spent on either side.
#[derive(Debug, Default)]
pub struct CheckpointStats {
    /// Checkpoints committed (staging dir renamed into place).
    saves: AtomicU64,
    /// Checkpoints restored into a session.
    restores: AtomicU64,
    /// Bucket files snapshotted or restored by hardlink (no byte copy).
    files_linked: AtomicU64,
    /// Bucket files snapshotted or restored by streaming copy.
    files_copied: AtomicU64,
    /// Payload bytes captured by hardlink (counted once per link).
    bytes_linked: AtomicU64,
    /// Payload bytes moved by streaming copy.
    bytes_copied: AtomicU64,
    /// Hardlinked files whose digest was **reused** from the prior
    /// manifest because their (inode, length) pair was unchanged — the
    /// differential-checkpoint fast path: a metadata stat instead of a
    /// full re-read.
    files_reused: AtomicU64,
    /// Payload bytes those reuses did *not* have to re-read.
    bytes_reused: AtomicU64,
    /// Wall nanoseconds spent inside `save` calls.
    save_ns: AtomicU64,
    /// Wall nanoseconds spent inside `restore` calls.
    restore_ns: AtomicU64,
}

impl CheckpointStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one committed save of duration `d`.
    pub fn add_save(&self, d: Duration) {
        self.saves.fetch_add(1, Ordering::Relaxed);
        self.save_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Charge one completed restore of duration `d`.
    pub fn add_restore(&self, d: Duration) {
        self.restores.fetch_add(1, Ordering::Relaxed);
        self.restore_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Charge one file captured by hardlink.
    pub fn add_link(&self, bytes: u64) {
        self.files_linked.fetch_add(1, Ordering::Relaxed);
        self.bytes_linked.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Charge one file captured by streaming copy.
    pub fn add_copy(&self, bytes: u64) {
        self.files_copied.fetch_add(1, Ordering::Relaxed);
        self.bytes_copied.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Charge one hardlinked file whose digest was reused from the prior
    /// manifest (no re-read).
    pub fn add_digest_reuse(&self, bytes: u64) {
        self.files_reused.fetch_add(1, Ordering::Relaxed);
        self.bytes_reused.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CheckpointSnapshot {
        CheckpointSnapshot {
            saves: self.saves.load(Ordering::Relaxed),
            restores: self.restores.load(Ordering::Relaxed),
            files_linked: self.files_linked.load(Ordering::Relaxed),
            files_copied: self.files_copied.load(Ordering::Relaxed),
            bytes_linked: self.bytes_linked.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            files_reused: self.files_reused.load(Ordering::Relaxed),
            bytes_reused: self.bytes_reused.load(Ordering::Relaxed),
            save_ns: self.save_ns.load(Ordering::Relaxed),
            restore_ns: self.restore_ns.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.saves.store(0, Ordering::Relaxed);
        self.restores.store(0, Ordering::Relaxed);
        self.files_linked.store(0, Ordering::Relaxed);
        self.files_copied.store(0, Ordering::Relaxed);
        self.bytes_linked.store(0, Ordering::Relaxed);
        self.bytes_copied.store(0, Ordering::Relaxed);
        self.files_reused.store(0, Ordering::Relaxed);
        self.bytes_reused.store(0, Ordering::Relaxed);
        self.save_ns.store(0, Ordering::Relaxed);
        self.restore_ns.store(0, Ordering::Relaxed);
    }
}

/// Plain-data snapshot of [`CheckpointStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CheckpointSnapshot {
    pub saves: u64,
    pub restores: u64,
    pub files_linked: u64,
    pub files_copied: u64,
    pub bytes_linked: u64,
    pub bytes_copied: u64,
    pub files_reused: u64,
    pub bytes_reused: u64,
    pub save_ns: u64,
    pub restore_ns: u64,
}

impl CheckpointSnapshot {
    /// Total bucket files touched (linked + copied).
    pub fn files_total(&self) -> u64 {
        self.files_linked + self.files_copied
    }

    /// Total payload bytes captured (linked + copied).
    pub fn bytes_total(&self) -> u64 {
        self.bytes_linked + self.bytes_copied
    }

    /// Human-readable one-line summary.
    pub fn report(&self) -> String {
        format!(
            "checkpoints: {} saved ({:.1} ms), {} restored ({:.1} ms), {} files hardlinked ({}), {} copied ({}), {} digests reused ({})",
            self.saves,
            self.save_ns as f64 / 1e6,
            self.restores,
            self.restore_ns as f64 / 1e6,
            self.files_linked,
            fmt_bytes(self.bytes_linked),
            self.files_copied,
            fmt_bytes(self.bytes_copied),
            self.files_reused,
            fmt_bytes(self.bytes_reused),
        )
    }
}

/// Per-worker counters for the collective execution pool
/// ([`crate::runtime::pool`]): how many bucket tasks each worker slot ran
/// and how long it was busy. Worker slots are stable across collectives
/// (slot `i` is always the `i`-th thread of a pool fan-out), so the rows
/// expose load-balance skew directly.
#[derive(Debug)]
pub struct PoolStats {
    tasks: Vec<AtomicU64>,
    busy_ns: Vec<AtomicU64>,
    /// In-collective op-capture volume: total record bytes captured
    /// (headers included).
    cap_bytes: AtomicU64,
    /// Capture bytes that overflowed to scratch files (the spill-backed
    /// space bound at work; 0 means every capture fit in its threshold).
    cap_spilled: AtomicU64,
    /// Capture scratch files created (per task × destination that
    /// spilled). Files are deleted after replay — this counts creations.
    cap_files: AtomicU64,
    /// Largest capture RAM any single task reached, transient append peak
    /// included — the observable form of the per-task space bound.
    cap_peak_task_ram: AtomicU64,
    /// Spills forced by the **flat per-task budget**: a push on one
    /// destination log pushed the task's total capture RAM over
    /// `capture_spill_threshold`, flushing the largest log to scratch.
    cap_budget_spills: AtomicU64,
    /// Tasks executed by their owning node's home worker (locality hits).
    locality_hits: AtomicU64,
    /// Tasks executed by any other worker — explicit steals under
    /// `StealPolicy::Bounded`, off-home cursor grabs under `Greedy`,
    /// always 0 under `Off`. `locality_hits + steals == total tasks`.
    steals: AtomicU64,
    /// Peak initial work-queue depth per node across collectives (queues
    /// only drain, so each collective's initial depth is its peak).
    node_depth: Mutex<Vec<u64>>,
}

impl PoolStats {
    /// Counters for a pool of `workers` slots.
    pub fn new(workers: usize) -> Self {
        PoolStats {
            tasks: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            busy_ns: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            cap_bytes: AtomicU64::new(0),
            cap_spilled: AtomicU64::new(0),
            cap_files: AtomicU64::new(0),
            cap_peak_task_ram: AtomicU64::new(0),
            cap_budget_spills: AtomicU64::new(0),
            locality_hits: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            node_depth: Mutex::new(Vec::new()),
        }
    }

    /// Number of worker slots.
    pub fn workers(&self) -> usize {
        self.tasks.len()
    }

    /// Charge one completed task of duration `d` to worker slot `w`.
    pub fn charge(&self, w: usize, d: Duration) {
        if let (Some(t), Some(b)) = (self.tasks.get(w), self.busy_ns.get(w)) {
            t.fetch_add(1, Ordering::Relaxed);
            b.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// `(tasks run, busy time)` for each worker slot.
    pub fn per_worker(&self) -> Vec<(u64, Duration)> {
        self.tasks
            .iter()
            .zip(self.busy_ns.iter())
            .map(|(t, b)| {
                (t.load(Ordering::Relaxed), Duration::from_nanos(b.load(Ordering::Relaxed)))
            })
            .collect()
    }

    /// Total tasks run across all worker slots.
    pub fn total_tasks(&self) -> u64 {
        self.tasks.iter().map(|t| t.load(Ordering::Relaxed)).sum()
    }

    /// Charge one finished task's op-capture footprint: bytes captured,
    /// bytes spilled to scratch, scratch files created, the task's peak
    /// capture RAM (folded into the pool-wide high-water mark), and how
    /// many spills the flat per-task budget forced.
    pub fn charge_capture(
        &self,
        bytes: u64,
        spilled: u64,
        files: u64,
        peak_ram: u64,
        budget_spills: u64,
    ) {
        self.cap_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.cap_spilled.fetch_add(spilled, Ordering::Relaxed);
        self.cap_files.fetch_add(files, Ordering::Relaxed);
        self.cap_peak_task_ram.fetch_max(peak_ram, Ordering::Relaxed);
        self.cap_budget_spills.fetch_add(budget_spills, Ordering::Relaxed);
    }

    /// Total op-capture record bytes (headers included).
    pub fn capture_bytes(&self) -> u64 {
        self.cap_bytes.load(Ordering::Relaxed)
    }

    /// Capture bytes that overflowed to scratch files.
    pub fn capture_spilled_bytes(&self) -> u64 {
        self.cap_spilled.load(Ordering::Relaxed)
    }

    /// Capture scratch files created (deleted again after replay).
    pub fn capture_scratch_files(&self) -> u64 {
        self.cap_files.load(Ordering::Relaxed)
    }

    /// Peak capture RAM any single task reached (bytes) — the per-task
    /// space bound made observable; tests assert it stays within the flat
    /// per-task `capture_spill_threshold` + one record, however many
    /// destination structures the task staged into.
    pub fn capture_peak_task_ram(&self) -> u64 {
        self.cap_peak_task_ram.load(Ordering::Relaxed)
    }

    /// Spills forced by the flat per-task capture budget (0 = every
    /// task's combined capture always fit in the threshold).
    pub fn capture_budget_spills(&self) -> u64 {
        self.cap_budget_spills.load(Ordering::Relaxed)
    }

    /// Charge one dequeued task against the locality counters: `local`
    /// when it ran on its owning node's home worker.
    pub fn add_locality(&self, local: bool) {
        if local {
            self.locality_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Tasks executed by their owning node's home worker.
    pub fn locality_hits(&self) -> u64 {
        self.locality_hits.load(Ordering::Relaxed)
    }

    /// Tasks executed off their home worker (steals / cursor grabs).
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Fraction of tasks that ran on their home worker (1.0 when no
    /// tasks have run — trivially local).
    pub fn locality_rate(&self) -> f64 {
        let hits = self.locality_hits();
        let total = hits + self.steals();
        if total == 0 {
            1.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Fold one collective's initial per-node queue depths into the
    /// per-node peaks (called once per pool fan-out).
    pub fn note_queue_depths(&self, depths: &[u64]) {
        let mut g = self.node_depth.lock().unwrap();
        if g.len() < depths.len() {
            g.resize(depths.len(), 0);
        }
        for (peak, &d) in g.iter_mut().zip(depths) {
            *peak = (*peak).max(d);
        }
    }

    /// Peak initial work-queue depth seen per node.
    pub fn per_node_queue_depth(&self) -> Vec<u64> {
        self.node_depth.lock().unwrap().clone()
    }

    /// Zero all counters (bench harness support).
    pub fn reset(&self) {
        for t in &self.tasks {
            t.store(0, Ordering::Relaxed);
        }
        for b in &self.busy_ns {
            b.store(0, Ordering::Relaxed);
        }
        self.cap_bytes.store(0, Ordering::Relaxed);
        self.cap_spilled.store(0, Ordering::Relaxed);
        self.cap_files.store(0, Ordering::Relaxed);
        self.cap_peak_task_ram.store(0, Ordering::Relaxed);
        self.cap_budget_spills.store(0, Ordering::Relaxed);
        self.locality_hits.store(0, Ordering::Relaxed);
        self.steals.store(0, Ordering::Relaxed);
        self.node_depth.lock().unwrap().clear();
    }

    /// Human-readable multi-line report (one row per worker slot).
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (w, (tasks, busy)) in self.per_worker().into_iter().enumerate() {
            s.push_str(&format!(
                "  worker {w:<3} {tasks:>8} tasks  {:>10.3} ms busy\n",
                busy.as_secs_f64() * 1e3
            ));
        }
        s.push_str(&format!(
            "  locality: {} home tasks, {} steals ({:.0}% local), peak node queue depths {:?}\n",
            self.locality_hits(),
            self.steals(),
            self.locality_rate() * 100.0,
            self.per_node_queue_depth(),
        ));
        s.push_str(&format!(
            "  capture: {} captured, {} spilled, {} scratch files, peak task ram {}, {} budget-forced spills\n",
            fmt_bytes(self.capture_bytes()),
            fmt_bytes(self.capture_spilled_bytes()),
            self.capture_scratch_files(),
            fmt_bytes(self.capture_peak_task_ram()),
            self.capture_budget_spills(),
        ));
        s
    }
}

/// Counters for the approximate-membership dedup tier
/// ([`crate::storage::bloom`]): how often the per-bucket filters were
/// probed and what they answered, how many exact-merge passes were
/// skipped outright (and how many bytes of exact-pass streaming those
/// skips avoided), and how much RAM the filters hold — the tier's
/// charge against the space bound.
#[derive(Debug, Default)]
pub struct DedupStats {
    /// Membership probes issued against any shard filter.
    pub probes: AtomicU64,
    /// Probes answered "definitely new" (the shortcut-eligible answer).
    pub definite_new: AtomicU64,
    /// Probes answered "maybe seen" (falls through to the exact pass in
    /// exact-backed mode; dropped as a duplicate in approximate mode).
    pub maybe_seen: AtomicU64,
    /// Records fed to the filters (every append path feeds them).
    pub inserts: AtomicU64,
    /// Exact-merge passes skipped entirely because the filter proved
    /// every candidate record new (per shard/bucket).
    pub shortcuts: AtomicU64,
    /// Exact-merge passes that still ran with the filter enabled
    /// (at least one "maybe seen" forced the full pass).
    pub exact_fallbacks: AtomicU64,
    /// Bytes of exact-pass streaming the shortcuts avoided (seen-set
    /// shards never read, bucket files never rewritten).
    pub bytes_avoided: AtomicU64,
    /// Records dropped as duplicates **without** an exact check
    /// (approximate mode only; 0 in exact-backed mode).
    pub approx_dropped: AtomicU64,
    /// High-water filter RAM across all structures (bytes).
    pub filter_ram_bytes: AtomicU64,
}

impl DedupStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one filter bank's current RAM into the high-water mark.
    pub fn note_ram(&self, bytes: u64) {
        self.filter_ram_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Charge one exact pass skipped outright, avoiding `bytes` of
    /// exact-pass streaming.
    pub fn add_shortcut(&self, bytes: u64) {
        self.shortcuts.fetch_add(1, Ordering::Relaxed);
        self.bytes_avoided.fetch_add(bytes, Ordering::Relaxed);
        crate::obs::trace::instant(
            crate::obs::trace::Kind::BloomShortcut,
            "bloom.shortcut",
            None,
            bytes,
            0,
        );
    }

    /// Charge one exact pass that had to run despite the filter.
    pub fn add_fallback(&self) {
        self.exact_fallbacks.fetch_add(1, Ordering::Relaxed);
        crate::obs::trace::instant(
            crate::obs::trace::Kind::BloomFallback,
            "bloom.fallback",
            None,
            0,
            0,
        );
    }

    /// Charge `n` records dropped by approximate mode without an exact
    /// check.
    pub fn add_approx_dropped(&self, n: u64) {
        self.approx_dropped.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> DedupSnapshot {
        DedupSnapshot {
            probes: self.probes.load(Ordering::Relaxed),
            definite_new: self.definite_new.load(Ordering::Relaxed),
            maybe_seen: self.maybe_seen.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            shortcuts: self.shortcuts.load(Ordering::Relaxed),
            exact_fallbacks: self.exact_fallbacks.load(Ordering::Relaxed),
            bytes_avoided: self.bytes_avoided.load(Ordering::Relaxed),
            approx_dropped: self.approx_dropped.load(Ordering::Relaxed),
            filter_ram_bytes: self.filter_ram_bytes.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.probes.store(0, Ordering::Relaxed);
        self.definite_new.store(0, Ordering::Relaxed);
        self.maybe_seen.store(0, Ordering::Relaxed);
        self.inserts.store(0, Ordering::Relaxed);
        self.shortcuts.store(0, Ordering::Relaxed);
        self.exact_fallbacks.store(0, Ordering::Relaxed);
        self.bytes_avoided.store(0, Ordering::Relaxed);
        self.approx_dropped.store(0, Ordering::Relaxed);
        self.filter_ram_bytes.store(0, Ordering::Relaxed);
    }
}

/// Plain-data snapshot of [`DedupStats`]; `+` aggregates instances
/// (filter RAM is a max, everything else sums).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DedupSnapshot {
    pub probes: u64,
    pub definite_new: u64,
    pub maybe_seen: u64,
    pub inserts: u64,
    pub shortcuts: u64,
    pub exact_fallbacks: u64,
    pub bytes_avoided: u64,
    pub approx_dropped: u64,
    pub filter_ram_bytes: u64,
}

impl DedupSnapshot {
    /// Fraction of probes answered "definitely new" (0.0 when none).
    pub fn definite_new_rate(&self) -> f64 {
        if self.probes == 0 {
            0.0
        } else {
            self.definite_new as f64 / self.probes as f64
        }
    }

    /// Human-readable one-line summary.
    pub fn report(&self) -> String {
        format!(
            "dedup filter: {} probes ({} definitely-new, {} maybe-seen), {} exact passes skipped ({} avoided), {} ran, {} approx-dropped, filter ram {}",
            self.probes,
            self.definite_new,
            self.maybe_seen,
            self.shortcuts,
            fmt_bytes(self.bytes_avoided),
            self.exact_fallbacks,
            self.approx_dropped,
            fmt_bytes(self.filter_ram_bytes),
        )
    }
}

impl std::ops::Add for DedupSnapshot {
    type Output = DedupSnapshot;
    fn add(self, o: DedupSnapshot) -> DedupSnapshot {
        DedupSnapshot {
            probes: self.probes + o.probes,
            definite_new: self.definite_new + o.definite_new,
            maybe_seen: self.maybe_seen + o.maybe_seen,
            inserts: self.inserts + o.inserts,
            shortcuts: self.shortcuts + o.shortcuts,
            exact_fallbacks: self.exact_fallbacks + o.exact_fallbacks,
            bytes_avoided: self.bytes_avoided + o.bytes_avoided,
            approx_dropped: self.approx_dropped + o.approx_dropped,
            filter_ram_bytes: self.filter_ram_bytes.max(o.filter_ram_bytes),
        }
    }
}

/// Counters for the scratch buffer pool ([`crate::storage::scratch`]):
/// how often hot-path loops reused a pooled buffer instead of hitting
/// the allocator, how much scratch RAM is on loan right now (and at
/// peak), how much idle RAM the pool itself retains (bounded by the
/// pool cap — tests assert this), and how many bytes flowed through
/// the flat decode arenas.
///
/// Two acquisition styles feed these counters differently: scoped
/// [`crate::storage::scratch::ScratchBuf`] guards maintain the
/// `outstanding*` loan gauges (their `Drop` runs even during unwind, so
/// a panicking collective leaks nothing — tests assert the gauge
/// returns to zero), while the raw take/put API used by the pipeline's
/// channel-circulated chunk buffers counts only hits/misses/pooled RAM
/// (those buffers' custody crosses threads, so a loan gauge would
/// miscount at teardown).
#[derive(Debug, Default)]
pub struct AllocStats {
    /// Buffer checkouts served from the pool (no allocator hit).
    pool_hits: AtomicU64,
    /// Buffer checkouts that had to allocate fresh (pool empty).
    pool_misses: AtomicU64,
    /// Buffers checked back in and retained for reuse.
    returns: AtomicU64,
    /// Buffers checked back in but freed (pool full or oversized).
    discards: AtomicU64,
    /// Gauge: scoped scratch buffers currently on loan.
    outstanding: AtomicU64,
    /// Gauge: capacity (bytes) of scoped scratch buffers on loan.
    outstanding_bytes: AtomicU64,
    /// High-water of `outstanding_bytes` — the peak scratch RAM any
    /// moment of the computation borrowed.
    peak_outstanding_bytes: AtomicU64,
    /// Gauge: idle RAM parked in the pool's free lists.
    pooled_bytes: AtomicU64,
    /// High-water of `pooled_bytes` — must stay ≤ the pool cap.
    peak_pooled_bytes: AtomicU64,
    /// Bytes decoded into flat arenas by the batch record codecs.
    arena_bytes: AtomicU64,
}

impl AllocStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one checkout; `bytes` is the handed-out capacity,
    /// `hit` whether the pool served it. `scoped` checkouts also move
    /// the loan gauges.
    pub fn on_checkout(&self, bytes: u64, hit: bool, scoped: bool) {
        if hit {
            self.pool_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.pool_misses.fetch_add(1, Ordering::Relaxed);
        }
        if scoped {
            self.outstanding.fetch_add(1, Ordering::Relaxed);
            let cur = self.outstanding_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
            self.peak_outstanding_bytes.fetch_max(cur, Ordering::Relaxed);
        }
    }

    /// Charge capacity growth of a scoped buffer while on loan.
    pub fn on_grow(&self, delta: u64) {
        let cur = self.outstanding_bytes.fetch_add(delta, Ordering::Relaxed) + delta;
        self.peak_outstanding_bytes.fetch_max(cur, Ordering::Relaxed);
    }

    /// Charge one check-in; `bytes` is the returned capacity, `kept`
    /// whether the pool retained it. `scoped` check-ins also move the
    /// loan gauges.
    pub fn on_checkin(&self, bytes: u64, kept: bool, scoped: bool) {
        if kept {
            self.returns.fetch_add(1, Ordering::Relaxed);
        } else {
            self.discards.fetch_add(1, Ordering::Relaxed);
        }
        if scoped {
            self.outstanding.fetch_sub(1, Ordering::Relaxed);
            self.outstanding_bytes.fetch_sub(bytes, Ordering::Relaxed);
        }
    }

    /// Record the pool's current idle RAM (called under the pool lock
    /// after every mutation).
    pub fn note_pooled(&self, bytes: u64) {
        self.pooled_bytes.store(bytes, Ordering::Relaxed);
        self.peak_pooled_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Charge `n` bytes decoded into a flat arena.
    pub fn add_arena_bytes(&self, n: u64) {
        self.arena_bytes.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> AllocSnapshot {
        AllocSnapshot {
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            discards: self.discards.load(Ordering::Relaxed),
            outstanding: self.outstanding.load(Ordering::Relaxed),
            outstanding_bytes: self.outstanding_bytes.load(Ordering::Relaxed),
            peak_outstanding_bytes: self.peak_outstanding_bytes.load(Ordering::Relaxed),
            pooled_bytes: self.pooled_bytes.load(Ordering::Relaxed),
            peak_pooled_bytes: self.peak_pooled_bytes.load(Ordering::Relaxed),
            arena_bytes: self.arena_bytes.load(Ordering::Relaxed),
        }
    }

    /// Zero the counters and high-water marks. The loan and pooled
    /// gauges are live custody state, not history, so they survive a
    /// reset (zeroing them would unbalance in-flight check-ins).
    pub fn reset(&self) {
        self.pool_hits.store(0, Ordering::Relaxed);
        self.pool_misses.store(0, Ordering::Relaxed);
        self.returns.store(0, Ordering::Relaxed);
        self.discards.store(0, Ordering::Relaxed);
        self.peak_outstanding_bytes
            .store(self.outstanding_bytes.load(Ordering::Relaxed), Ordering::Relaxed);
        self.peak_pooled_bytes
            .store(self.pooled_bytes.load(Ordering::Relaxed), Ordering::Relaxed);
        self.arena_bytes.store(0, Ordering::Relaxed);
    }
}

/// Plain-data snapshot of [`AllocStats`]; `+` aggregates pools (peaks
/// are maxes, everything else sums).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub pool_hits: u64,
    pub pool_misses: u64,
    pub returns: u64,
    pub discards: u64,
    pub outstanding: u64,
    pub outstanding_bytes: u64,
    pub peak_outstanding_bytes: u64,
    pub pooled_bytes: u64,
    pub peak_pooled_bytes: u64,
    pub arena_bytes: u64,
}

impl AllocSnapshot {
    /// Fraction of checkouts the pool served without allocating
    /// (0.0 when none happened).
    pub fn reuse_rate(&self) -> f64 {
        let total = self.pool_hits + self.pool_misses;
        if total == 0 {
            0.0
        } else {
            self.pool_hits as f64 / total as f64
        }
    }

    /// Human-readable one-line summary.
    pub fn report(&self) -> String {
        format!(
            "scratch pool: {} hits / {} misses ({:.0}% reuse), peak scratch ram {} (pooled idle {}), arena {}",
            self.pool_hits,
            self.pool_misses,
            self.reuse_rate() * 100.0,
            fmt_bytes(self.peak_outstanding_bytes),
            fmt_bytes(self.peak_pooled_bytes),
            fmt_bytes(self.arena_bytes),
        )
    }
}

impl std::ops::Add for AllocSnapshot {
    type Output = AllocSnapshot;
    fn add(self, o: AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            pool_hits: self.pool_hits + o.pool_hits,
            pool_misses: self.pool_misses + o.pool_misses,
            returns: self.returns + o.returns,
            discards: self.discards + o.discards,
            outstanding: self.outstanding + o.outstanding,
            outstanding_bytes: self.outstanding_bytes + o.outstanding_bytes,
            peak_outstanding_bytes: self.peak_outstanding_bytes.max(o.peak_outstanding_bytes),
            pooled_bytes: self.pooled_bytes + o.pooled_bytes,
            peak_pooled_bytes: self.peak_pooled_bytes.max(o.peak_pooled_bytes),
            arena_bytes: self.arena_bytes + o.arena_bytes,
        }
    }
}

/// Format a duration given in nanoseconds with an adaptive unit, for
/// report lines that range from sub-microsecond stalls to multi-second
/// collectives.
pub fn fmt_dur_ns(ns: u64) -> String {
    let v = ns as f64;
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.1} us", v / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1} ms", v / 1e6)
    } else {
        format!("{:.2} s", v / 1e9)
    }
}

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: &[&str] = &["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a rate in MB/s from bytes and seconds.
pub fn fmt_rate(bytes: u64, secs: f64) -> String {
    if secs <= 0.0 {
        return "inf".into();
    }
    format!("{:.1} MB/s", bytes as f64 / 1e6 / secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_dur_ns_picks_a_unit() {
        assert_eq!(fmt_dur_ns(0), "0 ns");
        assert_eq!(fmt_dur_ns(999), "999 ns");
        assert_eq!(fmt_dur_ns(1_500), "1.5 us");
        assert_eq!(fmt_dur_ns(2_500_000), "2.5 ms");
        assert_eq!(fmt_dur_ns(3_250_000_000), "3.25 s");
    }

    #[test]
    fn counters_accumulate() {
        let s = IoStats::new();
        s.add_read(100);
        s.add_read(50);
        s.add_write(30);
        s.add_seek();
        let snap = s.snapshot();
        assert_eq!(snap.bytes_read, 150);
        assert_eq!(snap.reads, 2);
        assert_eq!(snap.bytes_written, 30);
        assert_eq!(snap.writes, 1);
        assert_eq!(snap.seeks, 1);
        assert_eq!(snap.bytes_total(), 180);
    }

    #[test]
    fn snapshot_delta_and_add() {
        let s = IoStats::new();
        s.add_read(100);
        let a = s.snapshot();
        s.add_read(20);
        s.add_write(5);
        let b = s.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.bytes_read, 20);
        assert_eq!(d.bytes_written, 5);
        let sum = a + d;
        assert_eq!(sum.bytes_read, b.bytes_read);
    }

    #[test]
    fn reset_zeroes() {
        let s = IoStats::new();
        s.add_read(10);
        s.reset();
        assert_eq!(s.snapshot(), IoSnapshot::default());
    }

    #[test]
    fn phase_times_accumulate() {
        let p = PhaseTimes::new();
        p.add("sort", Duration::from_millis(5));
        p.add("sort", Duration::from_millis(7));
        p.add("apply", Duration::from_millis(1));
        assert_eq!(p.get("sort"), Some(Duration::from_millis(12)));
        let rows = p.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].2, 2);
        assert!(p.report().contains("sort"));
    }

    #[test]
    fn phase_time_closure() {
        let p = PhaseTimes::new();
        let v = p.time("work", || 42);
        assert_eq!(v, 42);
        assert!(p.get("work").is_some());
    }

    #[test]
    fn pool_stats_accumulate_and_reset() {
        let p = PoolStats::new(2);
        p.charge(0, Duration::from_millis(3));
        p.charge(0, Duration::from_millis(2));
        p.charge(1, Duration::from_millis(1));
        p.charge(9, Duration::from_millis(1)); // out of range: ignored
        assert_eq!(p.total_tasks(), 3);
        let rows = p.per_worker();
        assert_eq!(rows[0].0, 2);
        assert_eq!(rows[1].0, 1);
        assert!(rows[0].1 >= Duration::from_millis(5));
        assert!(p.report().contains("worker 0"));
        p.reset();
        assert_eq!(p.total_tasks(), 0);
    }

    #[test]
    fn pool_capture_counters() {
        let p = PoolStats::new(1);
        p.charge_capture(100, 60, 1, 48, 2);
        p.charge_capture(50, 0, 0, 32, 0); // smaller peak must not lower the max
        assert_eq!(p.capture_bytes(), 150);
        assert_eq!(p.capture_spilled_bytes(), 60);
        assert_eq!(p.capture_scratch_files(), 1);
        assert_eq!(p.capture_peak_task_ram(), 48);
        assert_eq!(p.capture_budget_spills(), 2);
        assert!(p.report().contains("capture:"), "{}", p.report());
        p.reset();
        assert_eq!(p.capture_bytes(), 0);
        assert_eq!(p.capture_peak_task_ram(), 0);
        assert_eq!(p.capture_budget_spills(), 0);
    }

    #[test]
    fn pool_locality_counters() {
        let p = PoolStats::new(2);
        p.add_locality(true);
        p.add_locality(true);
        p.add_locality(false);
        assert_eq!(p.locality_hits(), 2);
        assert_eq!(p.steals(), 1);
        assert!((p.locality_rate() - 2.0 / 3.0).abs() < 1e-9);
        p.note_queue_depths(&[3, 1]);
        p.note_queue_depths(&[2, 4, 5]); // grows, folds max per node
        assert_eq!(p.per_node_queue_depth(), vec![3, 4, 5]);
        assert!(p.report().contains("locality:"), "{}", p.report());
        p.reset();
        assert_eq!(p.steals(), 0);
        assert_eq!(p.locality_hits(), 0);
        assert_eq!(p.locality_rate(), 1.0, "no tasks is trivially local");
        assert!(p.per_node_queue_depth().is_empty());
    }

    #[test]
    fn pipeline_hint_counters() {
        let s = PipelineStats::new();
        s.add_hint_posted();
        s.add_hint_posted();
        s.add_hint_posted();
        s.add_hint_hit();
        s.add_hint_wastes(2);
        let snap = s.snapshot();
        assert_eq!(snap.hints_posted, 3);
        assert_eq!(snap.hint_hits, 1);
        assert_eq!(snap.hint_wastes, 2);
        assert!((snap.hint_hit_rate() - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(PipelineSnapshot::default().hint_hit_rate(), 0.0);
        let sum = snap + snap;
        assert_eq!(sum.hints_posted, 6);
        assert_eq!(sum.hint_hits, 2);
        s.reset();
        assert_eq!(s.snapshot().hints_posted, 0);
    }

    #[test]
    fn checkpoint_digest_reuse_counters() {
        let s = CheckpointStats::new();
        s.add_digest_reuse(128);
        s.add_digest_reuse(64);
        let snap = s.snapshot();
        assert_eq!(snap.files_reused, 2);
        assert_eq!(snap.bytes_reused, 192);
        assert!(snap.report().contains("digests reused"), "{}", snap.report());
        s.reset();
        assert_eq!(s.snapshot().files_reused, 0);
    }

    #[test]
    fn checkpoint_stats_accumulate_and_reset() {
        let s = CheckpointStats::new();
        s.add_save(Duration::from_millis(3));
        s.add_restore(Duration::from_millis(2));
        s.add_link(100);
        s.add_link(50);
        s.add_copy(30);
        let snap = s.snapshot();
        assert_eq!(snap.saves, 1);
        assert_eq!(snap.restores, 1);
        assert_eq!(snap.files_linked, 2);
        assert_eq!(snap.files_copied, 1);
        assert_eq!(snap.bytes_linked, 150);
        assert_eq!(snap.bytes_copied, 30);
        assert_eq!(snap.files_total(), 3);
        assert_eq!(snap.bytes_total(), 180);
        assert!(snap.save_ns >= 3_000_000 && snap.restore_ns >= 2_000_000);
        assert!(snap.report().contains("hardlinked"), "{}", snap.report());
        s.reset();
        assert_eq!(s.snapshot(), CheckpointSnapshot::default());
    }

    #[test]
    fn pipeline_stats_accumulate_and_aggregate() {
        let s = PipelineStats::new();
        s.add_stream();
        s.add_read_ahead(100);
        s.add_read_ahead(28);
        s.add_write_behind(64);
        s.add_reader_wait(Duration::from_micros(5));
        s.add_writer_wait(Duration::from_micros(7));
        s.note_stream_buf(512);
        s.note_stream_buf(256); // smaller must not lower the peak
        let a = s.snapshot();
        assert_eq!(a.streams, 1);
        assert_eq!(a.chunks_ahead, 2);
        assert_eq!(a.bytes_ahead, 128);
        assert_eq!(a.chunks_behind, 1);
        assert_eq!(a.bytes_behind, 64);
        assert!(a.reader_wait_ns >= 5_000 && a.writer_wait_ns >= 7_000);
        assert_eq!(a.peak_stream_buf, 512);

        let b = PipelineSnapshot { peak_stream_buf: 1024, streams: 2, ..Default::default() };
        let sum = a + b;
        assert_eq!(sum.streams, 3);
        assert_eq!(sum.peak_stream_buf, 1024, "aggregate peak is a max");

        s.reset();
        assert_eq!(s.snapshot(), PipelineSnapshot::default());
    }

    #[test]
    fn dedup_stats_accumulate_and_aggregate() {
        let s = DedupStats::new();
        s.probes.fetch_add(10, Ordering::Relaxed);
        s.definite_new.fetch_add(7, Ordering::Relaxed);
        s.maybe_seen.fetch_add(3, Ordering::Relaxed);
        s.inserts.fetch_add(5, Ordering::Relaxed);
        s.add_shortcut(1024);
        s.add_shortcut(512);
        s.add_fallback();
        s.add_approx_dropped(2);
        s.note_ram(4096);
        s.note_ram(2048); // smaller must not lower the high-water mark
        let a = s.snapshot();
        assert_eq!(a.probes, 10);
        assert_eq!(a.shortcuts, 2);
        assert_eq!(a.bytes_avoided, 1536);
        assert_eq!(a.exact_fallbacks, 1);
        assert_eq!(a.approx_dropped, 2);
        assert_eq!(a.filter_ram_bytes, 4096);
        assert!((a.definite_new_rate() - 0.7).abs() < 1e-9);
        assert_eq!(DedupSnapshot::default().definite_new_rate(), 0.0);
        let b = DedupSnapshot { filter_ram_bytes: 8192, probes: 1, ..Default::default() };
        let sum = a + b;
        assert_eq!(sum.probes, 11);
        assert_eq!(sum.filter_ram_bytes, 8192, "aggregate ram is a max");
        assert!(a.report().contains("exact passes skipped"), "{}", a.report());
        s.reset();
        assert_eq!(s.snapshot(), DedupSnapshot::default());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(2048).starts_with("2.00 KiB"));
        assert!(fmt_rate(1_000_000, 1.0).starts_with("1.0 MB/s"));
    }
}
