//! E8 — design-choice ablations called out in DESIGN.md:
//!
//! 1. **buckets_per_worker**: finer buckets shrink the RAM-resident sync
//!    unit but add per-bucket open/close overhead;
//! 2. **op_buffer_bytes**: smaller staging budgets spill more delayed-op
//!    bytes to disk before sync;
//! 3. **RoomySet vs RoomyList-as-set** (paper future work vs paper §3
//!    emulation): incremental sorted-merge vs removeDupes re-sorts;
//! 4. **Rubik pocket-cube**: second application end-to-end (hash-table
//!    BFS, RAM baseline).

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::testutil::Rng;

fn main() {
    println!("# E8: design-choice ablations");

    // ---- 1. buckets_per_worker sweep ---------------------------------
    header(
        "pancake n=8 (list) vs buckets_per_worker (4 workers)",
        &["buckets/worker", "wall s", "seeks"],
    );
    for bpw in [1usize, 2, 4, 8, 16] {
        let (_t, r) = fresh_roomy(&format!("ab-bpw{bpw}"), |c| {
            c.buckets_per_worker = bpw;
        });
        let before = r.io_snapshot();
        let (secs, stats) = time(|| {
            roomy::apps::pancake::roomy_bfs(
                &r,
                8,
                roomy::apps::pancake::Structure::List,
                &roomy::accel::Accel::rust(),
            )
            .unwrap()
        });
        assert_eq!(stats.total, roomy::apps::pancake::factorial(8));
        let io = r.io_snapshot().delta(&before);
        row(&[bpw.to_string(), format!("{secs:.2}"), io.seeks.to_string()]);
    }

    // ---- 2. op_buffer_bytes sweep -------------------------------------
    header(
        "1M random array updates vs staging budget",
        &["op_buffer", "stage+sync s", "spilled bytes"],
    );
    let n = scaled(1_000_000);
    for buf in [4 * 1024usize, 64 * 1024, 1024 * 1024, 8 * 1024 * 1024] {
        let (_t, r) = fresh_roomy(&format!("ab-buf{buf}"), |c| {
            c.op_buffer_bytes = buf;
        });
        let ra = r.array::<u64>("a", n, 0).unwrap();
        let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v += p);
        let mut rng = Rng::new(1);
        let (secs, spilled) = time(|| {
            for _ in 0..n {
                ra.update(rng.below(n), &1u64, add).unwrap();
            }
            let spilled = ra.pending_bytes();
            ra.sync().unwrap();
            spilled
        });
        row(&[
            format!("{}K", buf / 1024),
            format!("{secs:.2}"),
            spilled.to_string(),
        ]);
    }

    // ---- 3. RoomySet vs list-as-set ------------------------------------
    header(
        "incremental set (future work) vs removeDupes emulation",
        &["elements/round x rounds", "RoomySet s", "List+dedup s", "speedup"],
    );
    for (per_round, rounds) in [(scaled(50_000), 8u64), (scaled(200_000), 4)] {
        // RoomySet: sorted-merge sync per round
        let (_t, r1) = fresh_roomy("ab-set", |_| {});
        let s = r1.set::<u64>("s").unwrap();
        let mut rng = Rng::new(2);
        let (t_set, _) = time(|| {
            for _ in 0..rounds {
                for _ in 0..per_round {
                    s.add(&rng.below(per_round * 2)).unwrap();
                }
                s.sync().unwrap();
            }
        });
        // List emulation: sync + removeDupes per round (paper §3)
        let (_t2, r2) = fresh_roomy("ab-list", |_| {});
        let l = r2.list::<u64>("l").unwrap();
        let mut rng = Rng::new(2);
        let (t_list, _) = time(|| {
            for _ in 0..rounds {
                for _ in 0..per_round {
                    l.add(&rng.below(per_round * 2)).unwrap();
                }
                l.sync().unwrap();
                l.remove_dupes().unwrap();
            }
        });
        assert_eq!(s.size(), l.size(), "both must converge to the same set");
        row(&[
            format!("{per_round} x {rounds}"),
            format!("{t_set:.2}"),
            format!("{t_list:.2}"),
            format!("{:.2}x", t_list / t_set),
        ]);
    }

    // ---- 4. Rubik pocket cube end-to-end -------------------------------
    header(
        "2x2x2 Rubik's cube BFS (3.67M states, hash variant)",
        &["method", "wall s", "total states", "God's number"],
    );
    let (ram_s, ram_levels) = time(roomy::apps::rubik::reference_bfs);
    let (_t, r) = fresh_roomy("ab-rubik", |c| {
        c.buckets_per_worker = 4;
    });
    let (secs, stats) =
        time(|| roomy::apps::rubik::roomy_bfs(&r, &roomy::accel::Accel::rust()).unwrap());
    assert_eq!(stats.levels, ram_levels, "Roomy must match the RAM reference");
    row(&[
        "roomy (hash)".into(),
        format!("{secs:.1}"),
        stats.total.to_string(),
        stats.depth().to_string(),
    ]);
    row(&[
        "RAM reference".into(),
        format!("{ram_s:.1}"),
        ram_levels.iter().sum::<u64>().to_string(),
        (ram_levels.len() as u64 - 1).to_string(),
    ]);
}
