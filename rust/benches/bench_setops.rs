//! E5 "Table R3" — set algebra throughput (paper §3 Set Operations).
//!
//! Union, difference and both intersection variants over sets of
//! increasing size with ~50% overlap, plus the removeAll ablation:
//! hash-set filter (fits-in-RAM path) vs forced sorted-merge difference
//! (space-limited path). The paper notes its intersection construction is
//! sub-optimal; the "primitive" column quantifies the gap.

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::constructs::setops;
use roomy::{Roomy, RoomyList};

fn build(r: &Roomy, n: u64) -> (RoomyList<u64>, RoomyList<u64>) {
    let a = r.list::<u64>("A").unwrap();
    let b = r.list::<u64>("B").unwrap();
    for i in 0..n {
        a.add(&i).unwrap(); // A = 0..n
        b.add(&(i + n / 2)).unwrap(); // B = n/2..3n/2 (50% overlap)
    }
    a.sync().unwrap();
    b.sync().unwrap();
    setops::to_set(&a).unwrap();
    setops::to_set(&b).unwrap();
    (a, b)
}

fn main() {
    println!("# E5: set-operation throughput (50% overlap)");
    header(
        "set algebra wall time (s)",
        &["|A|=|B|", "union", "difference", "intersect (paper)", "intersect (primitive)", "Melts/s (union)"],
    );
    for n in [scaled(100_000), scaled(300_000), scaled(1_000_000)] {
        // union
        let (_t, r) = fresh_roomy(&format!("su{n}"), |_| {});
        let (a, b) = build(&r, n);
        let (t_union, _) = time(|| setops::union_into(&a, &b).unwrap());
        assert_eq!(a.size(), n + n / 2);

        // difference
        let (_t, r) = fresh_roomy(&format!("sd{n}"), |_| {});
        let (a, b) = build(&r, n);
        let (t_diff, _) = time(|| setops::difference_into(&a, &b).unwrap());
        assert_eq!(a.size(), n / 2);

        // intersections
        let (_t, r) = fresh_roomy(&format!("si{n}"), |_| {});
        let (a, b) = build(&r, n);
        let (t_int1, c1) = time(|| setops::intersection(&r, "C1", &a, &b).unwrap());
        let (t_int2, c2) =
            time(|| setops::intersection_primitive(&r, "C2", &a, &b).unwrap());
        assert_eq!(c1.size(), n - n / 2);
        assert_eq!(c2.size(), n - n / 2);

        row(&[
            n.to_string(),
            format!("{t_union:.2}"),
            format!("{t_diff:.2}"),
            format!("{t_int1:.2}"),
            format!("{t_int2:.2}"),
            format!("{:.2}", n as f64 / 1e6 / t_union),
        ]);
    }

    // ---- removeAll ablation: hash path vs sort-merge path ------------
    header(
        "removeAll ablation (|A|=|B|, 50% overlap)",
        &["|A|", "hash-filter s", "sort-merge s", "ratio"],
    );
    for n in [scaled(100_000), scaled(500_000)] {
        let run = |budget: usize| {
            let (_t, r) = fresh_roomy(&format!("sr{n}{budget}"), |c| {
                c.ram_budget_bytes = budget;
            });
            let (a, b) = build(&r, n);
            let (t, _) = time(|| a.remove_all(&b).unwrap());
            assert_eq!(a.size(), n / 2);
            t
        };
        let fast = run(usize::MAX / 2);
        let slow = run(1); // force sorted-merge
        row(&[
            n.to_string(),
            format!("{fast:.2}"),
            format!("{slow:.2}"),
            format!("{:.2}", slow / fast),
        ]);
    }
}
