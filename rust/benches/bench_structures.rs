//! E6 "Fig R3" — programming constructs scale streaming (paper §3).
//!
//! Wall time and bytes moved for map, map_update, reduce, chain
//! reduction, parallel prefix (log-round vs single-pass scan) and pair
//! reduction as the input grows. The shape to reproduce: every construct
//! is bandwidth-bound (time ∝ bytes moved), chain reduction ≈ 2 passes,
//! log-round prefix ≈ 2·log2(N) passes vs 2 passes for the scan kernel.

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::accel::Accel;
use roomy::constructs::{chainred, pairred, prefix};

fn main() {
    println!("# E6: construct scaling");
    header(
        "constructs over RoomyArray<i64> (wall s / MB moved)",
        &["N", "map", "map_update", "reduce", "chain red.", "prefix (log)", "prefix (scan)"],
    );
    for n in [scaled(100_000), scaled(1_000_000), scaled(4_000_000)] {
        // each construct gets a fresh instance so IO deltas are clean
        let mut cells = vec![n.to_string()];
        let map_cell = {
            let (_t, r) = fresh_roomy(&format!("st-map-{n}"), |_| {});
            let ra = r.array::<i64>("a", n, 0).unwrap();
            ra.map_update(|i, v| *v = i as i64).unwrap();
            let before = r.io_snapshot();
            let (secs, _) = time(|| ra.map(|_i, _v| {}).unwrap());
            let io = r.io_snapshot().delta(&before);
            record(&format!("map n={n}"), "secs", secs);
            record(&format!("map n={n}"), "mb_moved", io.bytes_total() as f64 / 1e6);
            format!("{secs:.2}s/{:.0}MB", io.bytes_total() as f64 / 1e6)
        };
        let map_update_cell = {
            let (_t, r) = fresh_roomy(&format!("st-mu-{n}"), |_| {});
            let ra = r.array::<i64>("a", n, 0).unwrap();
            let before = r.io_snapshot();
            let (secs, _) = time(|| ra.map_update(|_i, v| *v += 1).unwrap());
            let io = r.io_snapshot().delta(&before);
            record(&format!("map_update n={n}"), "secs", secs);
            record(&format!("map_update n={n}"), "mb_moved", io.bytes_total() as f64 / 1e6);
            format!("{secs:.2}s/{:.0}MB", io.bytes_total() as f64 / 1e6)
        };
        let reduce_cell = {
            let (_t, r) = fresh_roomy(&format!("st-red-{n}"), |_| {});
            let ra = r.array::<i64>("a", n, 1).unwrap();
            let before = r.io_snapshot();
            let (secs, v) = time(|| {
                ra.reduce(|| 0i64, |a, _i, v| a.wrapping_add(*v), |a, b| a.wrapping_add(b))
                    .unwrap()
            });
            assert_eq!(v, n as i64);
            let io = r.io_snapshot().delta(&before);
            record(&format!("reduce n={n}"), "secs", secs);
            record(&format!("reduce n={n}"), "mb_moved", io.bytes_total() as f64 / 1e6);
            format!("{secs:.2}s/{:.0}MB", io.bytes_total() as f64 / 1e6)
        };
        let chain_cell = {
            let (_t, r) = fresh_roomy(&format!("st-ch-{n}"), |_| {});
            let ra = r.array::<i64>("a", n, 1).unwrap();
            let before = r.io_snapshot();
            let (secs, _) =
                time(|| chainred::chain_reduce(&ra, |a, b| a.wrapping_add(*b)).unwrap());
            let io = r.io_snapshot().delta(&before);
            record(&format!("chain_reduce n={n}"), "secs", secs);
            record(&format!("chain_reduce n={n}"), "mb_moved", io.bytes_total() as f64 / 1e6);
            format!("{secs:.2}s/{:.0}MB", io.bytes_total() as f64 / 1e6)
        };
        let prefix_log_cell = {
            let (_t, r) = fresh_roomy(&format!("st-pl-{n}"), |_| {});
            let ra = r.array::<i64>("a", n, 1).unwrap();
            let before = r.io_snapshot();
            let (secs, _) =
                time(|| prefix::parallel_prefix(&ra, |a, b| a.wrapping_add(*b)).unwrap());
            let io = r.io_snapshot().delta(&before);
            record(&format!("prefix_log n={n}"), "secs", secs);
            record(&format!("prefix_log n={n}"), "mb_moved", io.bytes_total() as f64 / 1e6);
            format!("{secs:.2}s/{:.0}MB", io.bytes_total() as f64 / 1e6)
        };
        let prefix_scan_cell = {
            let (_t, r) = fresh_roomy(&format!("st-ps-{n}"), |_| {});
            let ra = r.array::<i64>("a", n, 1).unwrap();
            let before = r.io_snapshot();
            let (secs, _) =
                time(|| prefix::prefix_scan_array(&ra, &Accel::rust()).unwrap());
            let io = r.io_snapshot().delta(&before);
            record(&format!("prefix_scan n={n}"), "secs", secs);
            record(&format!("prefix_scan n={n}"), "mb_moved", io.bytes_total() as f64 / 1e6);
            format!("{secs:.2}s/{:.0}MB", io.bytes_total() as f64 / 1e6)
        };
        cells.extend([
            map_cell,
            map_update_cell,
            reduce_cell,
            chain_cell,
            prefix_log_cell,
            prefix_scan_cell,
        ]);
        row(&cells);
    }

    // pair reduction is O(N^2) delayed accesses: small N only
    header("pair reduction (N^2 delayed accesses)", &["N", "pairs", "wall s", "Mops/s"]);
    for n in [100u64, 300, 600] {
        let (_t, r) = fresh_roomy(&format!("st-pr-{n}"), |_| {});
        let ra = r.array::<i64>("a", n, 1).unwrap();
        let count = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let c2 = count.clone();
        let (secs, _) = time(|| {
            pairred::pair_reduction(&ra, move |_j, _inner, _i, _outer| {
                c2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            })
            .unwrap()
        });
        let pairs = count.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(pairs, n * n);
        record(&format!("pairred n={n}"), "secs", secs);
        record(&format!("pairred n={n}"), "mops_per_s", pairs as f64 / 1e6 / secs);
        row(&[
            n.to_string(),
            pairs.to_string(),
            format!("{secs:.2}"),
            format!("{:.2}", pairs as f64 / 1e6 / secs),
        ]);
    }

    write_baseline("structures");
}
