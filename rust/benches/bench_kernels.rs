//! E7 "Fig R4" — layer ablation: AOT XLA kernels vs the pure-Rust
//! fallbacks, per batch kernel — plus E9, the raw-speed kernel table:
//! scalar reference loops vs the batched/lane fingerprint kernels
//! (`hashfn::fp_bytes_batch_*`), the word-wise bitset kernels
//! (`roomy::bitkernels`), and the word-wise external-sort fast paths.
//!
//! Throughput of the four accel entry points on both backends. Context
//! for the numbers: the Pallas kernels are lowered with `interpret=True`
//! (mandatory for CPU PJRT in this image), so the XLA path measures the
//! *architecture* (AOT artifact + PJRT dispatch from the Rust hot path),
//! not TPU-class kernel speed — DESIGN.md §Hardware-Adaptation records
//! the VMEM/roofline estimates for real hardware. The scalar Rust twins
//! are the bit-exactness oracle and the practical CPU fast path.
//!
//! The E9 rows land in the machine-readable baseline
//! (`write_baseline("kernels")`), so CI's `kernels` variant can gate
//! kernel regressions with `roomy analyze-diff` against the committed
//! `benches/baselines/BENCH_baseline.json` — the diff only compares
//! groups present in both documents, so the one committed file carries
//! the structure rows and the kernel rows side by side.

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::accel::Accel;
use roomy::apps::pancake;
use roomy::testutil::Rng;

/// Pool scaling: structure map/reduce throughput at 1 vs N workers over
/// the same on-disk data. The per-element work is deliberately non-trivial
/// (fingerprint rounds) so the collective is CPU-bound, which is the
/// regime intra-node parallelism targets; the acceptance bar is ≥ 2× at
/// 4 workers.
fn pool_scaling() {
    header(
        "pool scaling: RoomyArray map/reduce (M elements/s)",
        &["collective", "elements", "1 worker", "4 workers", "speedup ×"],
    );
    let n = scaled(400_000);
    let work = |i: u64, v: u64| -> u64 {
        // ~8 fingerprint rounds per element: CPU-heavy map body
        let mut h = i ^ v;
        for _ in 0..8 {
            h = roomy::hashfn::fp_words(&[h]);
        }
        h
    };
    let mut rates = Vec::new();
    for nw in [1usize, 4] {
        let (_t, r) = fresh_roomy(&format!("poolscale{nw}"), |c| c.num_workers = nw);
        let ra = r.array::<u64>("a", n, 0).unwrap();
        ra.map_update(|i, v| *v = i.wrapping_mul(0x9E3779B97F4A7C15)).unwrap();
        let (tmap, _) = time_best(3, || {
            let sink = std::sync::atomic::AtomicU64::new(0);
            ra.map(|i, v| {
                sink.fetch_add(work(i, *v), std::sync::atomic::Ordering::Relaxed);
            })
            .unwrap();
            sink.into_inner()
        });
        let (tred, _) = time_best(3, || {
            ra.reduce(
                || 0u64,
                |acc, i, v| acc.wrapping_add(work(i, *v)),
                |a, b| a.wrapping_add(b),
            )
            .unwrap()
        });
        rates.push((nw, n as f64 / 1e6 / tmap, n as f64 / 1e6 / tred));
    }
    let (m1, r1) = (rates[0].1, rates[0].2);
    let (m4, r4) = (rates[1].1, rates[1].2);
    row(&[
        "map".into(),
        n.to_string(),
        format!("{m1:.1}"),
        format!("{m4:.1}"),
        format!("{:.2}", m4 / m1),
    ]);
    row(&[
        "reduce".into(),
        n.to_string(),
        format!("{r1:.1}"),
        format!("{r4:.1}"),
        format!("{:.2}", r4 / r1),
    ]);
}

/// Space-bound tax: a capture-heavy map/reduce (every element issues
/// delayed adds on another structure from inside the collective) with
/// RAM-resident capture (threshold far above the op volume) vs
/// spill-backed capture (tiny threshold forces scratch-file churn). Rows
/// track the throughput cost of the strict space bound.
fn capture_spill_overhead() {
    header(
        "op capture: RAM-resident vs spill-backed (M ops/s)",
        &["capture mode", "ops issued", "map M ops/s", "spilled", "scratch files"],
    );
    let n = scaled(200_000);
    for (label, threshold) in
        [("ram (64 MiB threshold)", 64usize << 20), ("spill (4 KiB threshold)", 4 << 10)]
    {
        let (_t, r) = fresh_roomy(&format!("capspill{threshold}"), |c| {
            c.num_workers = 4;
            c.capture_spill_threshold = threshold;
        });
        let src = r.list::<u64>("src").unwrap();
        for v in 0..n {
            src.add(&v).unwrap();
        }
        src.sync().unwrap();
        let dst = r.list::<u64>("dst").unwrap();
        let ops = 2 * n;
        let (tmap, _) = time_best(2, || {
            // counters reflect one rep (same volume every rep), not the
            // accumulation over warmup + measured runs
            r.cluster().pool().stats().reset();
            src.map(|&v| {
                dst.add(&(v ^ 0x5555)).unwrap();
                dst.add(&v.wrapping_mul(3)).unwrap();
            })
            .unwrap();
            dst.sync().unwrap();
        });
        let stats = r.cluster().pool().stats();
        row(&[
            label.into(),
            ops.to_string(),
            format!("{:.2}", ops as f64 / 1e6 / tmap),
            roomy::metrics::fmt_bytes(stats.capture_spilled_bytes()),
            stats.capture_scratch_files().to_string(),
        ]);
    }
}

/// E9: the raw-speed kernel table. Every row times a scalar reference
/// loop against its batched / word-wise replacement over identical data;
/// the kernels are bit-exact (pinned by `tests/property_tests.rs` and
/// the in-module props), so the ratio is pure speed. Acceptance bars:
/// ≥ 2× on batched fingerprints, ≥ 4× on word-wise bitset counting.
fn raw_speed_kernels() {
    use roomy::hashfn;
    use roomy::roomy::bitkernels::{self, CombineOp};

    header(
        &format!(
            "E9 raw-speed kernels: scalar vs batched/word-wise (dispatch: {})",
            hashfn::kernel_impl()
        ),
        &["kernel", "n", "scalar", "batched/word", "speedup ×"],
    );
    let mut rng = Rng::new(0xE9);

    // --- batched fingerprints: whole-chunk hashing, GB/s ---------------
    for rec_size in [8usize, 16] {
        let n = scaled(1_000_000) as usize;
        let batch = rng.bytes(n * rec_size);
        let bytes = (n * rec_size) as f64;
        let mut out: Vec<u64> = Vec::with_capacity(n);
        let (ts, _) = time_best(3, || {
            out.clear();
            out.extend(batch.chunks_exact(rec_size).map(hashfn::fp_bytes));
            *out.last().unwrap_or(&0)
        });
        let (tb, _) = time_best(3, || {
            hashfn::fp_bytes_batch_into(&batch, rec_size, &mut out);
            *out.last().unwrap_or(&0)
        });
        row(&[
            format!("fp_bytes rec={rec_size}"),
            n.to_string(),
            format!("{:.2} GB/s", bytes / 1e9 / ts),
            format!("{:.2} GB/s", bytes / 1e9 / tb),
            format!("{:.2}", ts / tb),
        ]);
        record(&format!("kern_fp_scalar rec={rec_size}"), "secs", ts);
        record(&format!("kern_fp_scalar rec={rec_size}"), "gb_per_s", bytes / 1e9 / ts);
        record(&format!("kern_fp_batched rec={rec_size}"), "secs", tb);
        record(&format!("kern_fp_batched rec={rec_size}"), "gb_per_s", bytes / 1e9 / tb);
    }

    // --- fused bucket routing: fingerprint + fast-range, M records/s ---
    {
        let rec_size = 8usize;
        let n = scaled(1_000_000) as usize;
        let batch = rng.bytes(n * rec_size);
        let mut routes: Vec<u32> = Vec::with_capacity(n);
        let (ts, _) = time_best(3, || {
            routes.clear();
            routes.extend(
                batch.chunks_exact(rec_size).map(|r| hashfn::bucket_of_bytes(r, 64)),
            );
            *routes.last().unwrap_or(&0)
        });
        let (tb, _) = time_best(3, || {
            hashfn::route_batch_into(&batch, rec_size, 64, &mut routes);
            *routes.last().unwrap_or(&0)
        });
        row(&[
            "route nb=64".into(),
            n.to_string(),
            format!("{:.1} M/s", n as f64 / 1e6 / ts),
            format!("{:.1} M/s", n as f64 / 1e6 / tb),
            format!("{:.2}", ts / tb),
        ]);
        record("kern_route_scalar nb=64", "secs", ts);
        record("kern_route_batched nb=64", "secs", tb);
    }

    // --- word-wise bitset counting: SWAR sweep vs shift/mask, G elems/s
    for bits in [1u8, 2] {
        let nbytes = scaled(4_000_000) as usize;
        let data = rng.bytes(nbytes);
        let per = (8 / bits) as u64;
        let nelems = nbytes as u64 * per;
        let mask = bitkernels::field_mask(bits);
        let (ts, cs) = time_best(3, || {
            let mut c = 0u64;
            for i in 0..nelems {
                let byte = data[(i / per) as usize];
                if (byte >> ((i % per) as u8 * bits)) & mask == 1 {
                    c += 1;
                }
            }
            c
        });
        let (tw, cw) = time_best(3, || bitkernels::count_value(&data, bits, nelems, 1));
        assert_eq!(cs, cw, "kernels disagree — property tests should have caught this");
        row(&[
            format!("bit count bits={bits}"),
            nelems.to_string(),
            format!("{:.2} G/s", nelems as f64 / 1e9 / ts),
            format!("{:.2} G/s", nelems as f64 / 1e9 / tw),
            format!("{:.2}", ts / tw),
        ]);
        record(&format!("kern_bitcount_scalar bits={bits}"), "secs", ts);
        record(&format!("kern_bitcount_word bits={bits}"), "secs", tw);
    }

    // --- set-algebra sweep: per-byte OR vs u64 OR over a 1-bit set -----
    {
        let nbytes = scaled(4_000_000) as usize;
        let a = rng.bytes(nbytes);
        let b = rng.bytes(nbytes);
        let mut dst = a.clone();
        let (ts, _) = time_best(3, || {
            dst.copy_from_slice(&a);
            for (d, s) in dst.iter_mut().zip(b.iter()) {
                *d |= *s;
            }
            dst[nbytes - 1]
        });
        let (tw, _) = time_best(3, || {
            dst.copy_from_slice(&a);
            bitkernels::combine_into(&mut dst, &b, CombineOp::Or);
            dst[nbytes - 1]
        });
        row(&[
            "set union (1-bit)".into(),
            (nbytes as u64 * 8).to_string(),
            format!("{:.2} GB/s", nbytes as f64 / 1e9 / ts),
            format!("{:.2} GB/s", nbytes as f64 / 1e9 / tw),
            format!("{:.2}", ts / tw),
        ]);
        record("kern_combine_scalar op=or", "secs", ts);
        record("kern_combine_word op=or", "secs", tw);
    }

    // --- word-wise external sort: dedup sort of u64 records, M recs/s --
    // (no scalar twin here — the fast path engages by record size; the
    // row gates absolute sort throughput in the baseline diff)
    {
        let n = scaled(400_000) as usize;
        let t = roomy::testutil::tmpdir("bench-kern-sort");
        let d = std::sync::Arc::new(
            roomy::storage::NodeDisk::create(0, t.path(), roomy::DiskPolicy::unthrottled())
                .unwrap(),
        );
        let mut w = roomy::storage::RecordWriter::create(&d, "in.dat", 8).unwrap();
        for _ in 0..n {
            w.push(&rng.below((n as u64 / 2).max(1)).to_be_bytes()).unwrap();
        }
        w.finish().unwrap();
        let (tsort, kept) = time_best(2, || {
            roomy::storage::extsort::sort_file(&d, "in.dat", "out.dat", 8, 4 << 20, true)
                .unwrap()
        });
        row(&[
            "extsort dedup rec=8".into(),
            format!("{n} ({kept} kept)"),
            "-".into(),
            format!("{:.2} M/s", n as f64 / 1e6 / tsort),
            "-".into(),
        ]);
        record("kern_sort_dedup rec=8", "secs", tsort);
        record("kern_sort_dedup rec=8", "mrecs_per_s", n as f64 / 1e6 / tsort);
    }
}

fn main() {
    println!("# E7+E9: kernel ablation (XLA AOT vs Rust) + raw-speed kernel pass");
    raw_speed_kernels();
    pool_scaling();
    capture_spill_overhead();
    xla_ablation();
    write_baseline("kernels");
}

fn xla_ablation() {
    let xla = {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            match roomy::runtime::Engine::load(dir) {
                Ok(e) => Some(Accel::xla(std::sync::Arc::new(e))),
                Err(e) => {
                    println!("artifacts present but engine failed to load ({e})");
                    None
                }
            }
        } else {
            None
        }
    };
    let rust = Accel::rust();
    let Some(xla) = xla else {
        println!("\nartifacts/ missing or unloadable — skipping the XLA ablation side");
        return;
    };

    let mut rng = Rng::new(7);
    header(
        "throughput (M elements/s), best of 3",
        &["kernel", "batch", "rust", "xla", "xla/rust ×"],
    );

    // hash_partition
    for count in [4096usize, 65_536, 262_144] {
        let words: Vec<u64> = (0..count).map(|_| rng.next_u64()).collect();
        let (tr, _) = time_best(3, || rust.hash_partition(&words, 1, 64).unwrap());
        let (tx, _) = time_best(3, || xla.hash_partition(&words, 1, 64).unwrap());
        let (mr, mx) = (count as f64 / 1e6 / tr, count as f64 / 1e6 / tx);
        row(&[
            "hash_partition".into(),
            count.to_string(),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }

    // prefix_scan
    for count in [4096usize, 65_536, 262_144] {
        let x: Vec<i64> = (0..count).map(|_| rng.range_i64(-1000, 1000)).collect();
        let (tr, _) = time_best(3, || rust.prefix_scan(&x).unwrap());
        let (tx, _) = time_best(3, || xla.prefix_scan(&x).unwrap());
        let (mr, mx) = (count as f64 / 1e6 / tr, count as f64 / 1e6 / tx);
        row(&[
            "prefix_scan".into(),
            count.to_string(),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }

    // reduce_sumsq
    for count in [4096usize, 262_144] {
        let x: Vec<i64> = (0..count).map(|_| rng.range_i64(-1000, 1000)).collect();
        let (tr, _) = time_best(3, || rust.reduce_sumsq(&x).unwrap());
        let (tx, _) = time_best(3, || xla.reduce_sumsq(&x).unwrap());
        let (mr, mx) = (count as f64 / 1e6 / tr, count as f64 / 1e6 / tx);
        row(&[
            "reduce_sumsq".into(),
            count.to_string(),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }

    // bfs_expand (per generated neighbor)
    for n in [8usize, 10, 12] {
        let frontier: Vec<u64> =
            (0..4096).map(|_| pancake::pack_perm(&rng.permutation(n))).collect();
        let nbrs = frontier.len() * (n - 1);
        let (tr, _) = time_best(3, || rust.bfs_expand(&frontier, n, 64).unwrap());
        let (tx, _) = time_best(3, || xla.bfs_expand(&frontier, n, 64).unwrap());
        let (mr, mx) = (nbrs as f64 / 1e6 / tr, nbrs as f64 / 1e6 / tx);
        row(&[
            format!("bfs_expand n={n}"),
            format!("4096 ({nbrs} nbrs)"),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }
}
