//! E7 "Fig R4" — layer ablation: AOT XLA kernels vs the pure-Rust
//! fallbacks, per batch kernel.
//!
//! Throughput of the four accel entry points on both backends. Context
//! for the numbers: the Pallas kernels are lowered with `interpret=True`
//! (mandatory for CPU PJRT in this image), so the XLA path measures the
//! *architecture* (AOT artifact + PJRT dispatch from the Rust hot path),
//! not TPU-class kernel speed — DESIGN.md §Hardware-Adaptation records
//! the VMEM/roofline estimates for real hardware. The scalar Rust twins
//! are the bit-exactness oracle and the practical CPU fast path.

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::accel::Accel;
use roomy::apps::pancake;
use roomy::testutil::Rng;

fn main() {
    println!("# E7: accel kernel ablation (XLA AOT vs Rust fallback)");
    let xla = {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            Some(Accel::xla(std::sync::Arc::new(
                roomy::runtime::Engine::load(dir).unwrap(),
            )))
        } else {
            None
        }
    };
    let rust = Accel::rust();
    let Some(xla) = xla else {
        println!("artifacts/ missing — run `make artifacts` for the XLA side");
        return;
    };

    let mut rng = Rng::new(7);
    header(
        "throughput (M elements/s), best of 3",
        &["kernel", "batch", "rust", "xla", "xla/rust ×"],
    );

    // hash_partition
    for count in [4096usize, 65_536, 262_144] {
        let words: Vec<u64> = (0..count).map(|_| rng.next_u64()).collect();
        let (tr, _) = time_best(3, || rust.hash_partition(&words, 1, 64).unwrap());
        let (tx, _) = time_best(3, || xla.hash_partition(&words, 1, 64).unwrap());
        let (mr, mx) = (count as f64 / 1e6 / tr, count as f64 / 1e6 / tx);
        row(&[
            "hash_partition".into(),
            count.to_string(),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }

    // prefix_scan
    for count in [4096usize, 65_536, 262_144] {
        let x: Vec<i64> = (0..count).map(|_| rng.range_i64(-1000, 1000)).collect();
        let (tr, _) = time_best(3, || rust.prefix_scan(&x).unwrap());
        let (tx, _) = time_best(3, || xla.prefix_scan(&x).unwrap());
        let (mr, mx) = (count as f64 / 1e6 / tr, count as f64 / 1e6 / tx);
        row(&[
            "prefix_scan".into(),
            count.to_string(),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }

    // reduce_sumsq
    for count in [4096usize, 262_144] {
        let x: Vec<i64> = (0..count).map(|_| rng.range_i64(-1000, 1000)).collect();
        let (tr, _) = time_best(3, || rust.reduce_sumsq(&x).unwrap());
        let (tx, _) = time_best(3, || xla.reduce_sumsq(&x).unwrap());
        let (mr, mx) = (count as f64 / 1e6 / tr, count as f64 / 1e6 / tx);
        row(&[
            "reduce_sumsq".into(),
            count.to_string(),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }

    // bfs_expand (per generated neighbor)
    for n in [8usize, 10, 12] {
        let frontier: Vec<u64> =
            (0..4096).map(|_| pancake::pack_perm(&rng.permutation(n))).collect();
        let nbrs = frontier.len() * (n - 1);
        let (tr, _) = time_best(3, || rust.bfs_expand(&frontier, n, 64).unwrap());
        let (tx, _) = time_best(3, || xla.bfs_expand(&frontier, n, 64).unwrap());
        let (mr, mx) = (nbrs as f64 / 1e6 / tr, nbrs as f64 / 1e6 / tx);
        row(&[
            format!("bfs_expand n={n}"),
            format!("4096 ({nbrs} nbrs)"),
            format!("{mr:.1}"),
            format!("{mx:.1}"),
            format!("{:.3}", mx / mr),
        ]);
    }
}
