//! Minimal shared bench harness (criterion is unavailable offline).
//!
//! Each `rust/benches/bench_*.rs` regenerates one experiment from
//! DESIGN.md's index (E1–E7) and prints a fixed-format table; the rows are
//! transcribed into EXPERIMENTS.md. Timing is wall-clock over full
//! collective operations — Roomy phases are seconds-scale streaming
//! passes, so single-shot timing with a warmup is appropriate (criterion
//! micro-sampling would add nothing).

#![allow(dead_code)]
#![allow(unused_imports)]

use std::sync::Mutex;
use std::time::Instant;

use roomy::{Roomy, RoomyConfig};

/// Time one run of `f` in seconds.
pub fn time<R>(f: impl FnOnce() -> R) -> (f64, R) {
    let t0 = Instant::now();
    let r = f();
    (t0.elapsed().as_secs_f64(), r)
}

/// Best-of-`reps` timing (first run is warmup when reps > 1).
pub fn time_best<R>(reps: usize, mut f: impl FnMut() -> R) -> (f64, R) {
    assert!(reps >= 1);
    let (mut best, mut out) = time(&mut f);
    for _ in 1..reps {
        let (t, r) = time(&mut f);
        if t < best {
            best = t;
            out = r;
        }
    }
    (best, out)
}

/// A fresh Roomy instance over a unique temp root.
pub fn fresh_roomy(tag: &str, f: impl FnOnce(&mut RoomyConfig)) -> (roomy::testutil::TmpDir, Roomy) {
    let t = roomy::testutil::tmpdir(&format!("bench-{tag}"));
    let mut cfg = RoomyConfig::for_testing(t.path());
    cfg.workers = 4;
    cfg.buckets_per_worker = 4;
    cfg.op_buffer_bytes = 4 * 1024 * 1024;
    cfg.sort_chunk_bytes = 64 * 1024 * 1024;
    f(&mut cfg);
    let r = Roomy::open(cfg).unwrap();
    (t, r)
}

/// Print a table header: `name | col | col | ...`.
pub fn header(title: &str, cols: &[&str]) {
    println!("\n### {title}");
    println!("| {} |", cols.join(" | "));
    println!("|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

/// Print one table row.
pub fn row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

/// MB/s from bytes and seconds.
pub fn mbps(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::INFINITY;
    }
    bytes as f64 / 1e6 / secs
}

/// Scale factor from env `ROOMY_BENCH_SCALE` (default 1.0) — lets CI run
/// the full matrix quickly and a workstation run it at size.
pub fn scale() -> f64 {
    std::env::var("ROOMY_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

pub fn scaled(n: u64) -> u64 {
    ((n as f64) * scale()).max(1.0) as u64
}

// ----------------------------------------------------------------------
// Machine-readable baseline: a flat sample list mirroring the printed
// tables, dumped as JSON so CI (and before/after comparisons) can diff
// runs without scraping markdown. The writer is the library's own
// hand-rolled JSON module (`roomy::obs::json`) — one escaper shared
// with `Roomy::report_json()` and the trace flusher; the CI bench smoke
// job asserts the file parses.
// ----------------------------------------------------------------------

/// The library escaper, re-exported so benches (and their tests) use
/// exactly what `BENCH_baseline.json` is written with.
pub use roomy::obs::json::escape as json_escape;

static SAMPLES: Mutex<Vec<(String, String, f64)>> = Mutex::new(Vec::new());

/// Record one `(group, metric, value)` sample for the JSON baseline,
/// e.g. `record("map n=1000000", "secs", 0.41)`.
pub fn record(group: &str, metric: &str, value: f64) {
    SAMPLES.lock().unwrap().push((group.to_string(), metric.to_string(), value));
}

/// The commit this bench binary was built from: `GITHUB_SHA` in CI, `git
/// rev-parse HEAD` on a workstation, `"unknown"` outside a checkout. Makes
/// two `BENCH_*.json` files diffable *across commits*, not just runs.
fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Snapshot of the `ROOMY_*` environment the run saw — the config axes
/// CI's matrix moves — so a baseline says what knobs produced it.
fn env_snapshot() -> Vec<(String, String)> {
    let mut vars: Vec<(String, String)> = std::env::vars()
        .filter(|(k, _)| k.starts_with("ROOMY_"))
        .collect();
    vars.sort();
    vars
}

/// Write every recorded sample to `BENCH_baseline.json` (path overridable
/// via `ROOMY_BENCH_JSON`). Call once at the end of a bench `main`.
///
/// Besides the samples, the document carries provenance: git SHA, unix
/// timestamp, bench scale, and the `ROOMY_*` env snapshot — enough to
/// know whether two baselines are comparable before `roomy analyze-diff`
/// compares them.
pub fn write_baseline(bench: &str) {
    use roomy::obs::json::{array, num, Obj};
    let path =
        std::env::var("ROOMY_BENCH_JSON").unwrap_or_else(|_| "BENCH_baseline.json".into());
    let samples = SAMPLES.lock().unwrap();
    let rows: Vec<String> = samples
        .iter()
        .map(|(group, metric, value)| {
            let mut r = Obj::new();
            // non-finite values (empty timing, div-by-zero rates) → null
            r.str("group", group).str("metric", metric).raw("value", &num(*value));
            r.build()
        })
        .collect();
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut env = Obj::new();
    for (k, v) in env_snapshot() {
        env.str(&k, &v);
    }
    let mut doc = Obj::new();
    doc.str("bench", bench)
        .raw("scale", &num(scale()))
        .str("git_sha", &git_sha())
        .u64("unix_secs", unix_secs)
        .raw("env", &env.build())
        .raw("samples", &array(&rows));
    let out = doc.build();
    std::fs::write(&path, &out).expect("write bench baseline JSON");
    println!("\nwrote {} samples to {path}", samples.len());
}
