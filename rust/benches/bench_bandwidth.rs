//! E1 "Fig R1" — aggregate disk bandwidth scales with the number of
//! disks/nodes (paper §1, Bandwidth), plus the overlapped-I/O ablation:
//! synchronous vs read-ahead/write-behind streaming
//! (`roomy::storage::pipeline`) at pipeline depths 0/1/2/4.
//!
//! A streaming `map` over a fixed-size RoomyArray under the paper's
//! 2010-era disk model (100 MB/s per disk). With W simulated node disks
//! the pass should complete ~W× faster: aggregate bandwidth ≈ W × 100 MB/s.
//! An unthrottled row shows the same scaling against host page-cache
//! speed.
//!
//! The overlap table uses a bulk rewrite (`map_update`: every byte read
//! once and written once) on ONE throttled node with ONE pool worker, so
//! cross-task overlap cannot hide the effect: at depth 0 the task pays
//! read-time + write-time serially; with the pipeline the read lane and
//! write lane sleep concurrently, so wall time approaches
//! max(read, write) ≈ half the synchronous pass.

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::DiskPolicy;

fn run(workers: usize, throttled: bool, total_bytes: u64) -> (f64, u64) {
    let n = total_bytes / 8;
    let (_t, r) = fresh_roomy(&format!("bw{workers}{throttled}"), |c| {
        c.workers = workers;
        c.buckets_per_worker = 2;
        if throttled {
            c.disk = DiskPolicy { read_bps: Some(100_000_000), write_bps: Some(100_000_000), seek_us: 0 };
        }
    });
    let ra = r.array::<u64>("a", n, 0).unwrap();
    r.cluster().reset_metrics();
    let before = r.io_snapshot();
    let (secs, _) = time(|| ra.map(|_i, _v| {}).unwrap());
    let io = r.io_snapshot().delta(&before);
    (secs, io.bytes_read)
}

/// One bulk rewrite pass (read N + write N bytes) at `depth`, single
/// throttled node, single pool worker. Returns (wall s, bytes moved).
fn run_overlap(depth: usize, total_bytes: u64) -> (f64, u64) {
    let n = total_bytes / 8;
    let (_t, r) = fresh_roomy(&format!("ov{depth}"), |c| {
        c.workers = 1;
        c.buckets_per_worker = 2;
        c.num_workers = 1;
        c.io_pipeline_depth = depth;
        c.disk = DiskPolicy {
            read_bps: Some(100_000_000),
            write_bps: Some(100_000_000),
            seek_us: 0,
        };
    });
    let ra = r.array::<u64>("a", n, 0).unwrap();
    r.cluster().reset_metrics();
    let before = r.io_snapshot();
    let (secs, _) = time(|| ra.map_update(|i, v| *v = i ^ *v).unwrap());
    let io = r.io_snapshot().delta(&before);
    let pipe = r.cluster().pipeline_snapshot();
    assert!(
        pipe.peak_stream_buf <= (depth.max(1) * roomy::storage::PIPE_CHUNK) as u64,
        "pipeline RAM bound violated at depth {depth}: {}",
        pipe.peak_stream_buf
    );
    (secs, io.bytes_total())
}

fn main() {
    // 64 MB payload: 0.64 s on one throttled disk, 80 ms on eight.
    let total = scaled(64 * 1024 * 1024);
    println!("# E1: aggregate streaming bandwidth vs #disks ({} payload)", total);

    header(
        "throttled (100 MB/s per simulated disk, paper's 2010 regime)",
        &["workers", "wall s", "aggregate MB/s", "per-disk MB/s", "scaling ×"],
    );
    let mut base = None;
    for w in [1usize, 2, 4, 8] {
        let (secs, bytes) = run(w, true, total);
        let agg = mbps(bytes, secs);
        let b = *base.get_or_insert(agg);
        row(&[
            w.to_string(),
            format!("{secs:.3}"),
            format!("{agg:.1}"),
            format!("{:.1}", agg / w as f64),
            format!("{:.2}", agg / b),
        ]);
    }

    header(
        "unthrottled (host speed)",
        &["workers", "wall s", "aggregate MB/s", "scaling ×"],
    );
    let mut base = None;
    for w in [1usize, 2, 4, 8] {
        // warmup + best-of-2 (page cache noise)
        let (_w, _) = run(w, false, total);
        let (s1, b1) = run(w, false, total);
        let (s2, b2) = run(w, false, total);
        let (secs, bytes) = if s1 < s2 { (s1, b1) } else { (s2, b2) };
        let agg = mbps(bytes, secs);
        let b = *base.get_or_insert(agg);
        row(&[
            w.to_string(),
            format!("{secs:.3}"),
            format!("{agg:.1}"),
            format!("{:.2}", agg / b),
        ]);
    }

    // Overlapped vs synchronous streaming: bulk rewrite, 1 node @ 100 MB/s
    // each direction, 1 pool worker. Depth 0 pays R+W serially; the
    // pipeline overlaps the two directions (and both with compute).
    let ov_total = scaled(24 * 1024 * 1024);
    header(
        "overlapped bucket I/O: bulk rewrite, 1 throttled node, 1 pool worker",
        &["io depth", "wall s", "MB/s moved", "speedup vs sync"],
    );
    let mut sync_secs = None;
    for depth in [0usize, 1, 2, 4] {
        let (secs, bytes) = run_overlap(depth, ov_total);
        let s0 = *sync_secs.get_or_insert(secs);
        row(&[
            depth.to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", mbps(bytes, secs)),
            format!("{:.2}x", s0 / secs),
        ]);
    }
}
