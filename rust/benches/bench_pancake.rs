//! E3 "Table R1" + E4 "Table R2" — the pancake-sorting flagship.
//!
//! E3: level counts for n = 2..=9 must match the in-RAM reference BFS and
//! the known pancake numbers (correctness table).
//!
//! E4: runtime of the three Roomy data-structure variants on n = 8, 9,
//! with the sort-phase share of the list variant broken out — reproducing
//! the paper's §2 claim that Array/HashTable's bucketing beats the
//! sort-dominated RoomyList.

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::accel::Accel;
use roomy::apps::pancake::{self, Structure};

fn main() {
    println!("# E3/E4: pancake sorting BFS");

    // ---- E3: correctness table --------------------------------------
    header(
        "E3: level counts vs reference (n=2..=8)",
        &["n", "n!", "f(n)", "known f(n)", "levels match", "total match"],
    );
    for n in 2..=8usize {
        let (_t, r) = fresh_roomy(&format!("pk{n}"), |_| {});
        let stats = pancake::roomy_bfs(&r, n, Structure::List, &Accel::rust()).unwrap();
        let reference = pancake::reference_bfs(n);
        row(&[
            n.to_string(),
            pancake::factorial(n).to_string(),
            stats.depth().to_string(),
            pancake::pancake_number(n).map(|v| v.to_string()).unwrap_or_default(),
            (stats.levels == reference).to_string(),
            (stats.total == pancake::factorial(n)).to_string(),
        ]);
    }

    // ---- E4: structure comparison -----------------------------------
    let xla = {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            roomy::runtime::Engine::load(dir)
                .ok()
                .map(|e| Accel::xla(std::sync::Arc::new(e)))
        } else {
            None
        }
    };

    for n in [8usize, 9] {
        header(
            &format!("E4: data-structure comparison, n={n} ({} states)", pancake::factorial(n)),
            &["structure", "wall s", "sort-phase share", "disk MB moved", "vs list ×"],
        );
        // RAM baseline first
        let (ram_s, _) = time(|| pancake::reference_bfs(n));
        let mut list_time = None;
        for (name, s) in [
            ("list", Structure::List),
            ("hash", Structure::Hash),
            ("array", Structure::Array),
        ] {
            let (_t, r) = fresh_roomy(&format!("pk{n}{name}"), |_| {});
            let accel = xla.clone().unwrap_or_else(Accel::rust);
            let before = r.io_snapshot();
            let (secs, stats) =
                time(|| pancake::roomy_bfs(&r, n, s, &accel).unwrap());
            assert_eq!(stats.total, pancake::factorial(n), "{name} must be exact");
            let io = r.io_snapshot().delta(&before);
            let phases = r.cluster().phases().rows();
            let total_phase: f64 =
                phases.iter().map(|(_, d, _)| d.as_secs_f64()).sum();
            let sort_phase: f64 = phases
                .iter()
                .filter(|(p, _, _)| p.contains("remove_dupes") || p.contains("remove_all"))
                .map(|(_, d, _)| d.as_secs_f64())
                .sum();
            let lt = *list_time.get_or_insert(secs);
            record(&format!("pancake_{name} n={n}"), "secs", secs);
            record(
                &format!("pancake_{name} n={n}"),
                "mb_moved",
                io.bytes_total() as f64 / 1e6,
            );
            row(&[
                name.into(),
                format!("{secs:.2}"),
                format!("{:.0}%", 100.0 * sort_phase / total_phase.max(1e-9)),
                format!("{:.1}", io.bytes_total() as f64 / 1e6),
                format!("{:.2}", lt / secs),
            ]);
        }
        row(&[
            "RAM reference".into(),
            format!("{ram_s:.2}"),
            "-".into(),
            "0".into(),
            "-".into(),
        ]);
    }
    // ---- E4b: checkpoint overhead -----------------------------------
    // Per-level save cost of the durable-checkpoint subsystem
    // (storage::checkpoint): wall overhead vs the plain driver, hardlink
    // vs copy split, and the cost of a full restore.
    let ckpt_n = if scale() < 0.1 { 6 } else { 8 };
    header(
        &format!("E4b: checkpoint overhead, n={ckpt_n} (list variant, checkpoint every level)"),
        &[
            "run",
            "wall s",
            "saves",
            "avg save ms",
            "linked files (MB)",
            "copied files (MB)",
            "restore ms",
        ],
    );
    {
        use roomy::constructs::bfs::{BfsOutcome, ResumableBfs};

        // plain driver baseline
        let (_t, r) = fresh_roomy("pkckpt_base", |_| {});
        let (base_s, _) = time(|| {
            pancake::roomy_bfs(&r, ckpt_n, Structure::List, &Accel::rust()).unwrap()
        });
        row(&[
            "no checkpoints".into(),
            format!("{base_s:.2}"),
            "0".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);

        // checkpoint-every-level run + one kill/resume to time restore
        let (_t2, r2) = fresh_roomy("pkckpt_run", |_| {});
        let mgr = r2.checkpoints().unwrap();
        let opts = ResumableBfs {
            manager: &mgr,
            tag: "pk".into(),
            stop_after_levels: Some(3),
        };
        let out =
            pancake::roomy_bfs_resumable(&r2, ckpt_n, Structure::List, &Accel::rust(), &opts)
                .unwrap();
        assert!(matches!(out, BfsOutcome::Suspended { .. }));
        let (full_s, out) = time(|| {
            pancake::roomy_bfs_resumable(
                &r2,
                ckpt_n,
                Structure::List,
                &Accel::rust(),
                &ResumableBfs::new(&mgr, "pk"),
            )
            .unwrap()
        });
        assert!(matches!(out, BfsOutcome::Complete(_)));
        let snap = mgr.stats().snapshot();
        row(&[
            "checkpoint/level (resumed)".into(),
            format!("{full_s:.2}"),
            snap.saves.to_string(),
            format!("{:.2}", snap.save_ns as f64 / 1e6 / snap.saves.max(1) as f64),
            format!("{} ({:.1})", snap.files_linked, snap.bytes_linked as f64 / 1e6),
            format!("{} ({:.1})", snap.files_copied, snap.bytes_copied as f64 / 1e6),
            format!("{:.2}", snap.restore_ns as f64 / 1e6 / snap.restores.max(1) as f64),
        ]);
    }

    // ---- E5: locality-aware pool scheduling -------------------------
    // The per-node work-queue engine (runtime::pool) vs the pre-refactor
    // flat cursor: pancake n=7 on a 4-wide pool with the I/O pipeline at
    // depth 2, one row per steal policy. `greedy` reproduces the old
    // flat-cursor schedule; `bounded` is the default home-first +
    // LIFO-steal policy; `off` is strict locality. All three produce
    // byte-identical state (tests/determinism.rs) — the columns show the
    // scheduling differences: wall time, how many tasks ran off their
    // home worker, the locality hit-rate, and how the cross-task
    // prefetch hints fared.
    {
        use roomy::StealPolicy;
        let e5_n = 7usize;
        header(
            &format!("E5: pool scheduling policy, pancake n={e5_n} (hash variant, 4 pool workers, io depth 2)"),
            &["policy", "wall s", "steals", "locality", "hints posted", "hint hits", "hint wastes"],
        );
        for (label, policy) in [
            ("greedy (flat cursor)", StealPolicy::Greedy),
            ("bounded (default)", StealPolicy::Bounded),
            ("off (strict locality)", StealPolicy::Off),
        ] {
            let (_t, r) = fresh_roomy(&format!("pk{e5_n}steal-{policy}"), |c| {
                c.num_workers = 4;
                c.io_pipeline_depth = 2;
                c.steal_policy = policy;
            });
            let (secs, stats) = time(|| {
                pancake::roomy_bfs(&r, e5_n, Structure::Hash, &Accel::rust()).unwrap()
            });
            assert_eq!(stats.total, pancake::factorial(e5_n), "{label} must be exact");
            let ps = r.cluster().pool().stats();
            let pipe = r.cluster().pipeline_snapshot();
            record(&format!("pancake_steal_{policy} n={e5_n}"), "secs", secs);
            row(&[
                label.into(),
                format!("{secs:.2}"),
                ps.steals().to_string(),
                format!("{:.0}%", ps.locality_rate() * 100.0),
                pipe.hints_posted.to_string(),
                format!("{} ({:.0}%)", pipe.hint_hits, pipe.hint_hit_rate() * 100.0),
                pipe.hint_wastes.to_string(),
            ]);
        }
    }

    // ---- E6: approximate-membership dedup tier ----------------------
    // The per-node bloom tier (storage::bloom) in front of the exact
    // sort-merge dedup. Three modes: off (seed behavior), exact-backed
    // (filter answers may skip exact work, every "maybe" falls through —
    // byte-identical state), and opt-in approximate (maybe == duplicate;
    // skips the exact merge entirely at a measured false-positive cost).
    {
        let e6_n = if scale() < 0.1 { 6 } else { 8 };
        header(
            &format!("E6: dedup tier, pancake n={e6_n} (list variant, 10 bits/key)"),
            &[
                "mode",
                "wall s",
                "exact-merge MB avoided",
                "filter RAM KB",
                "shortcuts",
                "fallbacks",
                "dropped",
            ],
        );
        let mut off_stats = None;
        for (label, bits, approx) in [
            ("off (exact only)", 0usize, false),
            ("exact-backed", 10, false),
            ("approximate", 10, true),
        ] {
            let (_t, r) = fresh_roomy(&format!("pk{e6_n}bloom-{bits}-{approx}"), |c| {
                c.bloom_bits_per_key = bits;
                c.bloom_approximate = approx;
            });
            let (secs, stats) = time(|| {
                pancake::roomy_bfs(&r, e6_n, Structure::List, &Accel::rust()).unwrap()
            });
            let snap = r.dedup_snapshot();
            match (bits, approx) {
                (0, _) => {
                    assert_eq!(stats.total, pancake::factorial(e6_n));
                    off_stats = Some(stats.clone());
                }
                (_, false) => {
                    // Exact-backed is transparent: identical level profile,
                    // with measurable exact-merge work avoided.
                    assert_eq!(Some(&stats), off_stats.as_ref(), "exact-backed diverged");
                    assert!(snap.bytes_avoided > 0, "no exact work avoided: {snap:?}");
                }
                (_, true) => {
                    // Approximate explores a subset: never more states than
                    // exact, and any shortfall is metered as dropped.
                    assert!(stats.total <= pancake::factorial(e6_n));
                }
            }
            row(&[
                label.into(),
                format!("{secs:.2}"),
                format!("{:.1}", snap.bytes_avoided as f64 / 1e6),
                format!("{:.1}", snap.filter_ram_bytes as f64 / 1e3),
                snap.shortcuts.to_string(),
                snap.exact_fallbacks.to_string(),
                snap.approx_dropped.to_string(),
            ]);
        }
    }

    // ---- E7: counter-driven self-tuning -----------------------------
    // runtime::autotune off (seed behavior) vs on: the controller reads
    // pipeline stall counters and pool queue-depth peaks between
    // collectives and moves each node's effective pipeline depth and the
    // cross-task hint distance. Both modes are byte-identical on disk
    // (tests/determinism.rs pins the digests) — only wall time and the
    // pipeline/hint counters may move.
    {
        use roomy::AutotuneMode;
        let e7_n = 7usize;
        header(
            &format!("E7: self-tuning, pancake n={e7_n} (hash variant, 4 pool workers, io depth 4)"),
            &["autotune", "wall s", "stalls r+w ms", "hint hits", "controller"],
        );
        for (label, mode) in [("off", AutotuneMode::Off), ("on", AutotuneMode::On)] {
            let (_t, r) = fresh_roomy(&format!("pk{e7_n}at-{label}"), |c| {
                c.num_workers = 4;
                c.io_pipeline_depth = 4;
                c.autotune = mode;
            });
            let (secs, stats) = time(|| {
                pancake::roomy_bfs(&r, e7_n, Structure::Hash, &Accel::rust()).unwrap()
            });
            assert_eq!(stats.total, pancake::factorial(e7_n), "autotune {label} must be exact");
            let pipe = r.cluster().pipeline_snapshot();
            record(&format!("pancake_autotune_{label} n={e7_n}"), "secs", secs);
            let controller = r
                .cluster()
                .autotune()
                .map(|at| at.report(r.cluster().disks()))
                .unwrap_or_else(|| "-".into());
            row(&[
                label.into(),
                format!("{secs:.2}"),
                format!("{:.1}", (pipe.reader_wait_ns + pipe.writer_wait_ns) as f64 / 1e6),
                pipe.hint_hits.to_string(),
                controller,
            ]);
        }
    }

    // ---- E8: flight-recorder overhead -------------------------------
    // obs::trace off (default; counters only) vs armed: the recorder
    // writes fixed-size events into pre-sized per-worker rings, so the
    // on-collective cost should be noise-level. On-disk bytes are
    // identical either way (tests/determinism.rs pins the digests) —
    // only wall time and the event volume may move.
    {
        let e8_n = 7usize;
        header(
            &format!("E8: flight recorder, pancake n={e8_n} (hash variant, 4 pool workers, io depth 4)"),
            &["trace", "wall s", "overhead vs off", "trace events", "trace KB"],
        );
        let mut off_secs = None;
        for (label, armed) in [("off", false), ("on", true)] {
            let tpath = std::env::temp_dir()
                .join(format!("roomy-bench-trace-{}.json", std::process::id()));
            let (_t, r) = fresh_roomy(&format!("pk{e8_n}tr-{label}"), |c| {
                c.num_workers = 4;
                c.io_pipeline_depth = 4;
                c.trace_path = if armed { Some(tpath.clone()) } else { None };
            });
            let (secs, stats) = time(|| {
                pancake::roomy_bfs(&r, e8_n, Structure::Hash, &Accel::rust()).unwrap()
            });
            assert_eq!(stats.total, pancake::factorial(e8_n), "trace {label} must be exact");
            record(&format!("pancake_trace_{label} n={e8_n}"), "secs", secs);
            let off = *off_secs.get_or_insert(secs);
            let (events, kb) = if armed {
                let flushed = r.flush_trace().unwrap().expect("trace must be armed");
                let text = std::fs::read_to_string(&flushed).expect("read flushed trace");
                let doc = roomy::obs::json::parse(&text).expect("trace must parse");
                let n = doc
                    .get("traceEvents")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.len())
                    .unwrap_or(0);
                let _ = std::fs::remove_file(&flushed);
                (n.to_string(), format!("{:.1}", text.len() as f64 / 1e3))
            } else {
                ("-".into(), "-".into())
            };
            row(&[
                label.into(),
                format!("{secs:.2}"),
                if armed { format!("{:+.1}%", 100.0 * (secs - off) / off.max(1e-9)) } else { "-".into() },
                events,
                kb,
            ]);
        }
    }

    println!(
        "\nexpansion backend: {}",
        if xla.is_some() { "XLA AOT (list/hash variants)" } else { "Rust fallback" }
    );
    write_baseline("pancake");
}
