//! E2 "Fig R2" — delayed batched operations vs immediate random access
//! (paper §1, Latency: "avoid latency penalties by using streaming data
//! access, instead of costly random access").
//!
//! Workload: M random read-modify-write updates into an N-element array
//! under the paper's disk model (5 ms seek, 100 MB/s streaming).
//!
//! - **Roomy**: stage M delayed updates, one `sync` applies them with
//!   streaming passes — cost ≈ (op log + array) bytes / bandwidth.
//! - **Naive**: each op seeks to its element (fetch with a charged seek).
//!   Executed for a small sample and reported per-op; the full-M cost is
//!   the per-op latency × M (extrapolated, labeled as such — actually
//!   sleeping 5 ms × 100 000 would take 8 minutes of wall clock to state
//!   the obvious).

#[path = "harness.rs"]
mod harness;

use harness::*;
use roomy::testutil::Rng;
use roomy::DiskPolicy;

fn main() {
    let n = scaled(1_000_000); // 8 MB array
    let m = scaled(100_000); // random updates
    let policy = DiskPolicy::paper_2010();
    println!("# E2: delayed batch vs immediate random access");
    println!("array {n} x u64, {m} random updates, disk = 100 MB/s + 5 ms seek\n");

    // ---- Roomy path -------------------------------------------------
    let (_t, r) = fresh_roomy("batch", |c| {
        c.workers = 4;
        c.disk = policy;
    });
    let ra = r.array::<u64>("a", n, 0).unwrap();
    let add = ra.register_update(|_i, v: &mut u64, p: &u64| *v = v.wrapping_add(*p));
    let mut rng = Rng::new(42);
    let (stage_s, _) = time(|| {
        for _ in 0..m {
            ra.update(rng.below(n), &1u64, add).unwrap();
        }
    });
    let before = r.io_snapshot();
    let (sync_s, _) = time(|| ra.sync().unwrap());
    let io = r.io_snapshot().delta(&before);
    let roomy_total = stage_s + sync_s;
    let roomy_per_op_us = roomy_total * 1e6 / m as f64;

    // ---- Naive path (sampled) ---------------------------------------
    let sample = 200.min(m);
    let mut rng = Rng::new(43);
    let (naive_s, _) = time(|| {
        for _ in 0..sample {
            // one random read is already one seek; a read-modify-write
            // would be two — we charge the cheaper one.
            let _ = ra.fetch(rng.below(n)).unwrap();
        }
    });
    let naive_per_op_us = naive_s * 1e6 / sample as f64;
    let naive_total_extrapolated = naive_per_op_us * m as f64 / 1e6;

    header(
        "results",
        &["method", "per-op µs", "total s", "notes"],
    );
    row(&[
        "Roomy delayed+sync".into(),
        format!("{roomy_per_op_us:.1}"),
        format!("{roomy_total:.2}"),
        format!(
            "stage {stage_s:.2}s + sync {sync_s:.2}s; {} streamed",
            roomy::metrics::fmt_bytes(io.bytes_total())
        ),
    ]);
    row(&[
        "naive random access".into(),
        format!("{naive_per_op_us:.1}"),
        format!("{naive_total_extrapolated:.1}"),
        format!("measured over {sample} ops, extrapolated to {m}"),
    ]);
    println!(
        "\nspeedup from batching: {:.0}x",
        naive_total_extrapolated / roomy_total
    );
}
