"""Layer 2: JAX compute graphs composing the Roomy L1 Pallas kernels.

Each public function here is an AOT entry point: ``aot.py`` lowers it once
to HLO text and the Rust coordinator (rust/src/runtime) loads and executes
it on the request path.  Python never runs at request time.

Entry points and their role in the Roomy runtime:

- ``hash_partition_k{1,2}``: fingerprint + bucket-route a batch of delayed
  ops / list elements (the shuffle hot loop).
- ``prefix_scan``: per-bucket inclusive scan for the parallel-prefix
  construct; L3 chains the returned block total across buckets.
- ``reduce_sumsq``: per-bucket partial reduction (paper's reduce example).
- ``bfs_expand_n{N}``: the fused pancake-BFS expansion — neighbors, packed
  codes, fingerprints and destination buckets in ONE lowered module, so the
  whole frontier expansion is a single PJRT call per batch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import hashpart, pancake, reduce as reduce_k, scan  # noqa: E402

# Fixed AOT batch sizes — mirrored in rust/src/runtime/shapes.rs. Rust pads
# partial batches (padding is routed to bucket ids that are ignored).
HASH_BATCH = 4096
SCAN_BATCH = 4096
REDUCE_BATCH = 4096
BFS_BATCH = 1024

# Pancake sizes for which we emit fused BFS-expansion artifacts.
PANCAKE_NS = (6, 7, 8, 9, 10, 11, 12)


def hash_partition_k1(words, nbuckets):
    """u64[HASH_BATCH,1] x u64[1] -> (fp u64[B], bucket u64[B])."""
    return hashpart.hash_partition(words, nbuckets, batch=HASH_BATCH, k=1)


def hash_partition_k2(words, nbuckets):
    """u64[HASH_BATCH,2] x u64[1] -> (fp u64[B], bucket u64[B])."""
    return hashpart.hash_partition(words, nbuckets, batch=HASH_BATCH, k=2)


def prefix_scan(x):
    """i64[SCAN_BATCH] -> (inclusive scan i64[B], total i64[1])."""
    return scan.scan_i64(x, batch=SCAN_BATCH)


def reduce_sumsq(x):
    """i64[REDUCE_BATCH] -> (sumsq i64[1], min i64[1], max i64[1])."""
    return reduce_k.reduce_i64(x, batch=REDUCE_BATCH)


def make_bfs_expand(n: int):
    """Fused frontier expansion for pancake size ``n``, on packed codes.

    u64[BFS_BATCH] x u64[1] ->
        (packed u64[B, n-1], fp u64[B, n-1], bucket u64[B, n-1])

    Packed (nibble) codes are the coordinator's wire format; the expansion
    kernel works directly on them with shift/mask arithmetic (see
    kernels/pancake.py for why the digit-gather variant is not AOT'd).
    """

    def bfs_expand(codes, nbuckets):
        packed = pancake.pancake_expand_packed(codes, batch=BFS_BATCH, n=n)
        flat = packed.reshape(BFS_BATCH * (n - 1), 1)
        # Reuse the SAME hashing math as the hashpart kernel so Rust-side
        # routing agrees bit-for-bit regardless of which path produced it.
        fp = hashpart.fp_words_jnp(flat)
        bucket = hashpart.bucket_of_jnp(fp, nbuckets[0])
        return (
            packed,
            fp.reshape(BFS_BATCH, n - 1),
            bucket.reshape(BFS_BATCH, n - 1),
        )

    bfs_expand.__name__ = f"bfs_expand_n{n}"
    return bfs_expand


def entry_points():
    """name -> (fn, example abstract args). Consumed by aot.py and tests."""
    u64 = jnp.uint64
    eps = {
        "hash_partition_k1": (
            hash_partition_k1,
            (
                jax.ShapeDtypeStruct((HASH_BATCH, 1), u64),
                jax.ShapeDtypeStruct((1,), u64),
            ),
        ),
        "hash_partition_k2": (
            hash_partition_k2,
            (
                jax.ShapeDtypeStruct((HASH_BATCH, 2), u64),
                jax.ShapeDtypeStruct((1,), u64),
            ),
        ),
        "prefix_scan": (
            prefix_scan,
            (jax.ShapeDtypeStruct((SCAN_BATCH,), jnp.int64),),
        ),
        "reduce_sumsq": (
            reduce_sumsq,
            (jax.ShapeDtypeStruct((REDUCE_BATCH,), jnp.int64),),
        ),
    }
    for n in PANCAKE_NS:
        eps[f"bfs_expand_n{n}"] = (
            make_bfs_expand(n),
            (
                jax.ShapeDtypeStruct((BFS_BATCH,), u64),
                jax.ShapeDtypeStruct((1,), u64),
            ),
        )
    return eps
