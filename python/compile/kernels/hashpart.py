"""L1 Pallas kernel: batched 64-bit fingerprint + fast-range bucket id.

This is the hot inner loop of Roomy's delayed-operation shuffle: every
delayed op / list element is fingerprinted and routed to the bucket that
owns it.  The kernel is the bit-exact twin of ``rust/src/hashfn.rs`` and of
``ref.fp_words`` — pinned by shared test vectors.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch is tiled along the
grid so each block's (BLOCK, K) u64 slab fits comfortably in VMEM; the body
is pure VPU element-wise integer work (xor/mul/shift), no MXU. interpret=True
is mandatory in this image — real-TPU lowering emits a Mosaic custom-call
that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain python ints (NOT jnp scalars): Pallas kernels may not capture traced
# constants from the enclosing scope; literals are inlined at trace time.
GOLDEN = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB

# Block of the batch dimension held in VMEM at once. 512 x K x 8B plus the
# two u64 outputs is < 16 KiB for K <= 2 — far under the ~16 MiB VMEM
# budget; kept small so many programs pipeline HBM<->VMEM transfers.
BLOCK = 512


def fp_words_jnp(words: jnp.ndarray) -> jnp.ndarray:
    """splitmix-style avalanche over the trailing K axis. uint64[..., K] -> uint64[...]."""
    k = words.shape[-1]
    h = jnp.full(words.shape[:-1], jnp.uint64(GOLDEN ^ k), dtype=jnp.uint64)
    for i in range(k):  # K is static: unrolled
        h = (h ^ words[..., i]) * jnp.uint64(MIX1)
        h = h ^ (h >> jnp.uint64(29))
    h = h ^ (h >> jnp.uint64(30))
    h = h * jnp.uint64(MIX1)
    h = h ^ (h >> jnp.uint64(27))
    h = h * jnp.uint64(MIX2)
    h = h ^ (h >> jnp.uint64(31))
    return h


def bucket_of_jnp(fp: jnp.ndarray, nbuckets: jnp.ndarray) -> jnp.ndarray:
    """Fast-range bucket id: ((fp >> 32) * nb) >> 32 (nb < 2^32)."""
    return ((fp >> jnp.uint64(32)) * nbuckets.astype(jnp.uint64)) >> jnp.uint64(32)


def _hashpart_kernel(nb_ref, words_ref, fp_ref, bucket_ref):
    """One grid step: fingerprint + bucket a (BLOCK, K) slab of elements."""
    fp = fp_words_jnp(words_ref[...])
    fp_ref[...] = fp
    bucket_ref[...] = bucket_of_jnp(fp, nb_ref[0])


@functools.partial(jax.jit, static_argnames=("batch", "k"))
def hash_partition(words: jnp.ndarray, nbuckets: jnp.ndarray, *, batch: int, k: int):
    """(fingerprint u64[B], bucket u64[B]) for words u64[B, K].

    ``batch`` must be a multiple of BLOCK (the AOT entry points use 4096).
    ``nbuckets`` is a u64[1] runtime scalar so one artifact serves any
    bucket count.
    """
    assert batch % BLOCK == 0, f"batch {batch} must be a multiple of {BLOCK}"
    grid = (batch // BLOCK,)
    return pl.pallas_call(
        _hashpart_kernel,
        grid=grid,
        in_specs=[
            # nbuckets scalar: replicated to every program.
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.uint64),
            jax.ShapeDtypeStruct((batch,), jnp.uint64),
        ],
        interpret=True,
    )(nbuckets, words)
