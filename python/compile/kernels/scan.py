"""L1 Pallas kernel: blocked inclusive prefix scan (int64 sum).

Per-bucket kernel of the parallel-prefix / chain-reduction constructs
(paper §3): Layer 3 streams each Roomy bucket through this kernel and
propagates the per-bucket carry itself, exactly mirroring how Roomy
propagates partial sums across disk buckets.

TPU mapping: the grid walks the batch sequentially; an SMEM scratch cell
carries the running total between grid steps — the canonical Pallas
sequential-accumulator pattern.  Each step scans one VMEM-resident BLOCK.
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 4096


def _scan_kernel(x_ref, y_ref, total_ref, carry_ref):
    """One grid step: local inclusive scan + carry-in from previous blocks."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        carry_ref[0] = jnp.int64(0)

    carry = carry_ref[0]
    # Hillis–Steele log-step inclusive scan. NOT jnp.cumsum: that lowers
    # to reduce_window, which the CPU backend executes in O(n·window) —
    # quadratic in the block (§Perf P4).
    local = x_ref[...]
    n = local.shape[0]
    shift = 1
    while shift < n:
        shifted = jnp.concatenate(
            [jnp.zeros((shift,), dtype=local.dtype), local[:-shift]]
        )
        local = local + shifted
        shift *= 2
    y_ref[...] = local + carry
    carry_ref[0] = carry + local[-1]
    total_ref[0] = carry_ref[0]


@functools.partial(jax.jit, static_argnames=("batch",))
def scan_i64(x: jnp.ndarray, *, batch: int):
    """Inclusive prefix sum of int64[batch]; also returns the grand total.

    Returns (scan int64[batch], total int64[1]).
    """
    assert batch % BLOCK == 0, f"batch {batch} must be a multiple of {BLOCK}"
    grid = (batch // BLOCK,)
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            # total: every step overwrites; the last write wins.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.int64),
            jax.ShapeDtypeStruct((1,), jnp.int64),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int64)],
        interpret=True,
    )(x)
