"""L1 Pallas kernel: batched numeric reduction (sum of squares, min, max).

The paper's reduce example (§3) computes a sum of squares over a RoomyList;
Layer 3 streams each bucket through this kernel and merges the per-bucket
partials with the user's ``mergeResults`` — exactly the two-function reduce
contract from the paper (assoc + comm).

TPU mapping: sequential grid with SMEM accumulators carried across steps
(same pattern as scan.py); each step reduces one VMEM-resident BLOCK on
the VPU.
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024

# Plain python ints: Pallas kernels may not capture traced constants.
I64_MAX = 0x7FFF_FFFF_FFFF_FFFF
I64_MIN = -0x8000_0000_0000_0000


def _reduce_kernel(x_ref, sumsq_ref, min_ref, max_ref, acc_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[0] = jnp.int64(0)
        acc_ref[1] = jnp.int64(I64_MAX)
        acc_ref[2] = jnp.int64(I64_MIN)

    x = x_ref[...]
    # Wrapping sum-of-squares: do the multiply in uint64 and bit-cast back,
    # matching Rust's wrapping_mul/wrapping_add semantics.
    xu = x.astype(jnp.uint64)
    sq = (xu * xu).sum(dtype=jnp.uint64).astype(jnp.int64)
    acc_ref[0] = (acc_ref[0].astype(jnp.uint64) + sq.astype(jnp.uint64)).astype(
        jnp.int64
    )
    acc_ref[1] = jnp.minimum(acc_ref[1], x.min())
    acc_ref[2] = jnp.maximum(acc_ref[2], x.max())
    sumsq_ref[0] = acc_ref[0]
    min_ref[0] = acc_ref[1]
    max_ref[0] = acc_ref[2]


@functools.partial(jax.jit, static_argnames=("batch",))
def reduce_i64(x: jnp.ndarray, *, batch: int):
    """(sumsq int64[1], min int64[1], max int64[1]) over int64[batch]."""
    assert batch % BLOCK == 0, f"batch {batch} must be a multiple of {BLOCK}"
    grid = (batch // BLOCK,)
    return pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.int64),
            jax.ShapeDtypeStruct((1,), jnp.int64),
            jax.ShapeDtypeStruct((1,), jnp.int64),
        ],
        scratch_shapes=[pltpu.SMEM((3,), jnp.int64)],
        interpret=True,
    )(x)
