"""L1 Pallas kernel: batched pancake prefix-reversal neighbor expansion.

The hot spot of the paper's flagship application (§3, breadth-first search
over the pancake-sorting graph): for every permutation in the frontier,
emit all n-1 prefix reversals.  Layer 2 (model.py) fuses this with the
fingerprint/bucket kernel so one AOT artifact turns a frontier batch into
routed neighbor records.

TPU mapping: the reversal is a static gather — for block shape (BLOCK, N)
the kernel materializes the (N-1, N) source-index matrix as a constant and
does a vectorized take along the lane axis.  No MXU; VMEM per step is
BLOCK * N * 4B * N ≈ tiny for n <= 16.
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 256


def reversal_index_matrix(n: int) -> np.ndarray:
    """M[j, i]: source index for neighbor j (flip of first j+2), position i."""
    m = np.empty((n - 1, n), dtype=np.int32)
    for j in range(n - 1):
        k = j + 2
        for i in range(n):
            m[j, i] = k - 1 - i if i < k else i
    return m


def _expand_kernel(m_ref, perms_ref, nbrs_ref):
    """One grid step: all prefix reversals of a (BLOCK, N) slab.

    The (N-1, N) source-index matrix is passed as a (replicated) input:
    Pallas kernels may not capture non-scalar constants from the trace.
    """
    p = perms_ref[...]  # (BLOCK, N)
    # (BLOCK, N-1, N): gather source positions per neighbor row.
    nbrs_ref[...] = jnp.take(p, m_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("batch", "n"))
def pancake_expand(perms: jnp.ndarray, *, batch: int, n: int):
    """All prefix reversals: int32[batch, n] -> int32[batch, n-1, n]."""
    assert batch % BLOCK == 0, f"batch {batch} must be a multiple of {BLOCK}"
    grid = (batch // BLOCK,)
    m = jnp.asarray(reversal_index_matrix(n))
    return pl.pallas_call(
        _expand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n - 1, n), lambda i: (0, 0)),  # index matrix, replicated
            pl.BlockSpec((BLOCK, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK, n - 1, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n - 1, n), jnp.int32),
        interpret=True,
    )(m, perms)


def pack_perm_u64_jnp(perms: jnp.ndarray) -> jnp.ndarray:
    """Nibble-pack permutations of 0..n-1 (n <= 16): int32[..., N] -> uint64[...]."""
    n = perms.shape[-1]
    assert n <= 16
    out = jnp.zeros(perms.shape[:-1], dtype=jnp.uint64)
    for i in range(n):
        out = out | (perms[..., i].astype(jnp.uint64) << jnp.uint64(4 * i))
    return out


# ---------------------------------------------------------------------------
# Packed-code expansion: the AOT production path.
#
# The digit-matrix kernel above uses a gather (`jnp.take`), which the Rust
# runtime's xla_extension 0.5.1 misexecutes after the HLO-text round-trip
# (out-of-bounds fill). The packed variant below uses only u64 shift/mask
# arithmetic — the same op family as the hashpart kernel, which round-trips
# correctly — and matches the coordinator's wire format (frontiers are
# nibble-packed u64 codes on the Rust side anyway).
# ---------------------------------------------------------------------------


def flip_packed_jnp(code: jnp.ndarray, k: int) -> jnp.ndarray:
    """Reverse the first k nibbles of packed codes (k static, unrolled).

    Bit-exact twin of rust `apps::pancake::flip_packed`.
    """
    bits = 4 * k
    mask = (1 << bits) - 1
    inv_mask = ~mask & 0xFFFFFFFFFFFFFFFF
    head = code & jnp.uint64(mask)
    rev = jnp.zeros_like(code)
    for _ in range(k):
        rev = (rev << jnp.uint64(4)) | (head & jnp.uint64(0xF))
        head = head >> jnp.uint64(4)
    return (code & jnp.uint64(inv_mask)) | rev


def _expand_packed_kernel(n: int, codes_ref, nbrs_ref):
    """One grid step: all prefix reversals of a (BLOCK,) slab of packed codes."""
    c = codes_ref[...]
    for j, k in enumerate(range(2, n + 1)):
        nbrs_ref[:, j] = flip_packed_jnp(c, k)


@functools.partial(jax.jit, static_argnames=("batch", "n"))
def pancake_expand_packed(codes: jnp.ndarray, *, batch: int, n: int):
    """All prefix reversals on packed codes: u64[batch] -> u64[batch, n-1]."""
    assert batch % BLOCK == 0, f"batch {batch} must be a multiple of {BLOCK}"
    grid = (batch // BLOCK,)
    return pl.pallas_call(
        functools.partial(_expand_packed_kernel, n),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK, n - 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n - 1), jnp.uint64),
        interpret=True,
    )(codes)
