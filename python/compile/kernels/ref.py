"""Pure-numpy reference oracles for the Roomy L1 kernels.

These are the CORE correctness signal for the build-time layer: every
Pallas kernel in this package is checked against the function of the same
name here (pytest + hypothesis), and the fingerprint/bucket functions are
additionally pinned to hard test vectors that the Rust twin
(``rust/src/hashfn.rs``) asserts too — the partitioner must be bit-exact
across layers or Roomy's bucketing breaks.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# 64-bit fingerprint (splitmix-style avalanche), u64 wrapping arithmetic.
# Twin: rust/src/hashfn.rs (fp_words / bucket_of). Keep in lockstep.
# ---------------------------------------------------------------------------

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
MIX1 = np.uint64(0xBF58476D1CE4E5B9)
MIX2 = np.uint64(0x94D049BB133111EB)


def _u64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.uint64)


def fp_words(words: np.ndarray) -> np.ndarray:
    """Fingerprint of an array of K-word elements.

    words: uint64[..., K] -> uint64[...]
    """
    words = _u64(words)
    k = words.shape[-1]
    with np.errstate(over="ignore"):
        h = np.full(words.shape[:-1], GOLDEN ^ np.uint64(k), dtype=np.uint64)
        for i in range(k):
            h = (h ^ words[..., i]) * MIX1
            h = h ^ (h >> np.uint64(29))
        h = h ^ (h >> np.uint64(30))
        h = h * MIX1
        h = h ^ (h >> np.uint64(27))
        h = h * MIX2
        h = h ^ (h >> np.uint64(31))
    return h


def bucket_of(fp: np.ndarray, nbuckets: int) -> np.ndarray:
    """Fast-range bucket id from a fingerprint: ((fp>>32) * nb) >> 32."""
    fp = _u64(fp)
    nb = np.uint64(nbuckets)
    with np.errstate(over="ignore"):
        return ((fp >> np.uint64(32)) * nb) >> np.uint64(32)


def hash_partition(words: np.ndarray, nbuckets: int):
    """(fingerprints, bucket ids) for a batch of K-word elements."""
    fp = fp_words(words)
    return fp, bucket_of(fp, nbuckets)


# ---------------------------------------------------------------------------
# Inclusive prefix scan (int64 sum) — the parallel-prefix / chain-reduction
# per-bucket kernel.
# ---------------------------------------------------------------------------


def scan_i64(x: np.ndarray) -> np.ndarray:
    """Inclusive prefix sum, wrapping int64."""
    x = np.asarray(x, dtype=np.int64)
    with np.errstate(over="ignore"):
        return np.cumsum(x.astype(np.uint64)).astype(np.int64)


# ---------------------------------------------------------------------------
# Pancake prefix-reversal neighbor expansion.
# ---------------------------------------------------------------------------


def reversal_index_matrix(n: int) -> np.ndarray:
    """M[j, i]: source index for neighbor j (flip of first j+2), position i."""
    m = np.empty((n - 1, n), dtype=np.int32)
    for j in range(n - 1):
        k = j + 2  # flip length, 2..n
        for i in range(n):
            m[j, i] = k - 1 - i if i < k else i
    return m


def pancake_expand(perms: np.ndarray) -> np.ndarray:
    """perms int32[B, N] -> neighbors int32[B, N-1, N] (flip first k=2..N)."""
    perms = np.asarray(perms, dtype=np.int32)
    n = perms.shape[-1]
    m = reversal_index_matrix(n)
    return perms[:, m]


def pack_perm_u64(perms: np.ndarray) -> np.ndarray:
    """Nibble-pack a permutation of 0..n-1 (n <= 16) into a u64 word.

    int32[..., N] -> uint64[...]
    """
    perms = np.asarray(perms)
    n = perms.shape[-1]
    assert n <= 16, "nibble packing supports n <= 16"
    out = np.zeros(perms.shape[:-1], dtype=np.uint64)
    with np.errstate(over="ignore"):
        for i in range(n):
            out |= perms[..., i].astype(np.uint64) << np.uint64(4 * i)
    return out


def bfs_expand(perms: np.ndarray, nbuckets: int):
    """Fused BFS expansion: neighbors + packed codes + fingerprints + buckets.

    Returns (nbrs int32[B,N-1,N], packed u64[B,N-1], fp u64[B,N-1],
    bucket u64[B,N-1]).
    """
    nbrs = pancake_expand(perms)
    packed = pack_perm_u64(nbrs)
    fp = fp_words(packed[..., None])
    return nbrs, packed, fp, bucket_of(fp, nbuckets)


def flip_packed(code: np.ndarray, k: int) -> np.ndarray:
    """Reverse the first k nibbles of packed codes (twin of rust
    ``apps::pancake::flip_packed`` and the packed Pallas kernel)."""
    code = _u64(code)
    bits = 4 * k
    mask = np.uint64((1 << bits) - 1)
    inv_mask = np.uint64(~((1 << bits) - 1) & 0xFFFFFFFFFFFFFFFF)
    head = code & mask
    rev = np.zeros_like(code)
    for _ in range(k):
        rev = (rev << np.uint64(4)) | (head & np.uint64(0xF))
        head = head >> np.uint64(4)
    return (code & inv_mask) | rev


def pancake_expand_packed(codes: np.ndarray, n: int) -> np.ndarray:
    """All prefix reversals on packed codes: u64[B] -> u64[B, n-1]."""
    codes = _u64(codes)
    return np.stack([flip_packed(codes, k) for k in range(2, n + 1)], axis=-1)


def bfs_expand_packed(codes: np.ndarray, n: int, nbuckets: int):
    """Packed fused expansion: (packed u64[B,n-1], fp, bucket)."""
    packed = pancake_expand_packed(codes, n)
    fp = fp_words(packed[..., None])
    return packed, fp, bucket_of(fp, nbuckets)


# ---------------------------------------------------------------------------
# Batched numeric reduction (the paper's reduce example: sum of squares).
# ---------------------------------------------------------------------------


def reduce_i64(x: np.ndarray):
    """(sum of squares, min, max) over int64[B], wrapping arithmetic."""
    x = np.asarray(x, dtype=np.int64)
    with np.errstate(over="ignore"):
        xx = x.astype(np.uint64)
        sumsq = (xx * xx).sum(dtype=np.uint64)
    return sumsq.astype(np.int64), x.min(), x.max()
