"""AOT exporter: lower every L2 entry point to HLO text for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs, under --outdir (default ../artifacts):
  <name>.hlo.txt     one per entry point
  manifest.tsv       name, file, arity and shape summary (runtime contract)

Lowering is deterministic and pure; ``make artifacts`` skips this entirely
when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_sig(args) -> str:
    return ";".join(f"{a.dtype}[{','.join(map(str, a.shape))}]" for a in args)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    eps = model.entry_points()
    if args.only:
        want = set(args.only.split(","))
        eps = {k: v for k, v in eps.items() if k in want}
        missing = want - set(eps)
        if missing:
            print(f"unknown entry points: {sorted(missing)}", file=sys.stderr)
            return 1

    manifest_rows = []
    for name, (fn, ex_args) in sorted(eps.items()):
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        manifest_rows.append((name, fname, shape_sig(ex_args)))
        print(f"  lowered {name:24s} -> {fname} ({len(text)} chars)")

    with open(os.path.join(args.outdir, "manifest.tsv"), "w") as f:
        for name, fname, sig in manifest_rows:
            f.write(f"{name}\t{fname}\t{sig}\n")
    print(f"wrote {len(manifest_rows)} artifacts to {args.outdir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
