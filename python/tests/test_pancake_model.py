"""pancake expansion kernel + fused L2 bfs_expand model vs oracles."""

import itertools

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: deterministic seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from compile import model
from compile.kernels import pancake, ref


def random_perms(rng, b, n):
    return np.array([rng.permutation(n) for _ in range(b)], dtype=np.int32)


def test_expand_matches_ref():
    rng = np.random.default_rng(0)
    b, n = pancake.BLOCK, 7
    perms = random_perms(rng, b, n)
    nbrs = pancake.pancake_expand(jnp.asarray(perms), batch=b, n=n)
    np.testing.assert_array_equal(np.asarray(nbrs), ref.pancake_expand(perms))


def test_expand_small_exhaustive_n4():
    """All 24 perms of n=4: every neighbor is the correct prefix reversal."""
    perms = np.array(list(itertools.permutations(range(4))), dtype=np.int32)
    perms = np.tile(perms, (pancake.BLOCK // 24 + 1, 1))[: pancake.BLOCK]
    nbrs = np.asarray(pancake.pancake_expand(jnp.asarray(perms), batch=pancake.BLOCK, n=4))
    for bi in range(24):
        p = perms[bi]
        for j in range(3):
            k = j + 2
            expect = np.concatenate([p[:k][::-1], p[k:]])
            np.testing.assert_array_equal(nbrs[bi, j], expect)


def test_neighbors_are_permutations():
    rng = np.random.default_rng(1)
    b, n = pancake.BLOCK, 9
    perms = random_perms(rng, b, n)
    nbrs = np.asarray(pancake.pancake_expand(jnp.asarray(perms), batch=b, n=n))
    sorted_last = np.sort(nbrs, axis=-1)
    np.testing.assert_array_equal(
        sorted_last, np.broadcast_to(np.arange(n, dtype=np.int32), sorted_last.shape)
    )


def test_involution():
    """Flipping the same prefix twice returns the original permutation."""
    rng = np.random.default_rng(2)
    b, n = pancake.BLOCK, 8
    perms = random_perms(rng, b, n)
    nbrs = np.asarray(pancake.pancake_expand(jnp.asarray(perms), batch=b, n=n))
    for j in range(n - 1):
        again = ref.pancake_expand(nbrs[:, j, :])[:, j, :]
        np.testing.assert_array_equal(again, perms)


def test_pack_roundtrip():
    rng = np.random.default_rng(3)
    perms = random_perms(rng, 64, 10)
    packed = ref.pack_perm_u64(perms)
    # unpack and compare
    unpacked = np.zeros_like(perms)
    for i in range(10):
        unpacked[:, i] = ((packed >> np.uint64(4 * i)) & np.uint64(0xF)).astype(
            np.int32
        )
    np.testing.assert_array_equal(unpacked, perms)
    jpacked = np.asarray(pancake.pack_perm_u64_jnp(jnp.asarray(perms)))
    np.testing.assert_array_equal(jpacked, packed)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=6, max_value=12), st.integers(min_value=0, max_value=10**6))
def test_hypothesis_expand_all_n(n, seed):
    rng = np.random.default_rng(seed)
    b = pancake.BLOCK
    perms = random_perms(rng, b, n)
    nbrs = pancake.pancake_expand(jnp.asarray(perms), batch=b, n=n)
    np.testing.assert_array_equal(np.asarray(nbrs), ref.pancake_expand(perms))


def test_packed_expand_kernel_matches_ref():
    rng = np.random.default_rng(5)
    n, b = 9, pancake.BLOCK * 2
    perms = random_perms(rng, b, n)
    codes = ref.pack_perm_u64(perms)
    out = pancake.pancake_expand_packed(jnp.asarray(codes), batch=b, n=n)
    np.testing.assert_array_equal(np.asarray(out), ref.pancake_expand_packed(codes, n))


def test_packed_expand_agrees_with_digit_expand():
    """Packed shift/mask reversal == digit-matrix gather reversal."""
    rng = np.random.default_rng(6)
    n, b = 8, pancake.BLOCK
    perms = random_perms(rng, b, n)
    codes = ref.pack_perm_u64(perms)
    packed = ref.pancake_expand_packed(codes, n)
    digits = ref.pancake_expand(perms)
    np.testing.assert_array_equal(packed, ref.pack_perm_u64(digits))


def test_flip_packed_involution():
    rng = np.random.default_rng(7)
    codes = ref.pack_perm_u64(random_perms(rng, 100, 10))
    for k in range(2, 11):
        np.testing.assert_array_equal(
            ref.flip_packed(ref.flip_packed(codes, k), k), codes
        )


def test_fused_bfs_expand_model():
    """L2 fused graph == composition of oracles (incl. routing agreement)."""
    rng = np.random.default_rng(4)
    n, nb = 8, 37
    perms = random_perms(rng, model.BFS_BATCH, n)
    codes = ref.pack_perm_u64(perms)
    fn = model.make_bfs_expand(n)
    packed, fp, bucket = fn(jnp.asarray(codes), jnp.asarray([nb], dtype=jnp.uint64))
    epacked, efp, ebucket = ref.bfs_expand_packed(codes, n, nb)
    np.testing.assert_array_equal(np.asarray(packed), epacked)
    np.testing.assert_array_equal(np.asarray(fp), efp)
    np.testing.assert_array_equal(np.asarray(bucket), ebucket)


def test_entry_points_lower():
    """Every AOT entry point traces and lowers to StableHLO without error."""
    import jax

    for name, (fn, ex_args) in model.entry_points().items():
        lowered = jax.jit(fn).lower(*ex_args)
        ir = lowered.compiler_ir("stablehlo")
        assert ir is not None, name


def test_fused_model_all_aot_sizes():
    """Every AOT'd bfs_expand_n{N} matches the oracle composition."""
    rng = np.random.default_rng(11)
    for n in model.PANCAKE_NS:
        perms = random_perms(rng, model.BFS_BATCH, n)
        codes = ref.pack_perm_u64(perms)
        fn = model.make_bfs_expand(n)
        packed, fp, bucket = fn(
            jnp.asarray(codes), jnp.asarray([17], dtype=jnp.uint64)
        )
        epacked, efp, ebucket = ref.bfs_expand_packed(codes, n, 17)
        np.testing.assert_array_equal(np.asarray(packed), epacked, err_msg=f"n={n}")
        np.testing.assert_array_equal(np.asarray(fp), efp, err_msg=f"n={n}")
        np.testing.assert_array_equal(np.asarray(bucket), ebucket, err_msg=f"n={n}")
