"""hashpart Pallas kernel vs pure-numpy oracle + cross-language pin vectors."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: deterministic seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from compile.kernels import hashpart, ref

BATCH = hashpart.BLOCK * 2  # small multiple for test speed


def run_kernel(words, nbuckets, k):
    b = words.shape[0]
    fp, bucket = hashpart.hash_partition(
        jnp.asarray(words, dtype=jnp.uint64),
        jnp.asarray([nbuckets], dtype=jnp.uint64),
        batch=b,
        k=k,
    )
    return np.asarray(fp), np.asarray(bucket)


@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("nbuckets", [1, 2, 7, 64, 1024])
def test_kernel_matches_ref(k, nbuckets):
    rng = np.random.default_rng(42 + k)
    words = rng.integers(0, 2**64, size=(BATCH, k), dtype=np.uint64)
    fp, bucket = run_kernel(words, nbuckets, k)
    efp, ebucket = ref.hash_partition(words, nbuckets)
    np.testing.assert_array_equal(fp, efp)
    np.testing.assert_array_equal(bucket, ebucket)


def test_bucket_range():
    rng = np.random.default_rng(7)
    words = rng.integers(0, 2**64, size=(BATCH, 1), dtype=np.uint64)
    for nb in (1, 3, 17, 255):
        _, bucket = run_kernel(words, nb, 1)
        assert bucket.max() < nb
        assert bucket.min() >= 0


def test_fingerprint_k_sensitivity():
    """Same leading word must hash differently for k=1 vs k=2 (length mixed in)."""
    w = np.uint64(0xDEADBEEF12345678)
    one = ref.fp_words(np.array([[w]], dtype=np.uint64))[0]
    two = ref.fp_words(np.array([[w, np.uint64(0)]], dtype=np.uint64))[0]
    assert one != two


# Cross-language pin: rust/src/hashfn.rs asserts these SAME vectors.
# (generated from ref.fp_words; do not regenerate casually — they define
# the on-disk routing contract)
PIN_VECTORS_K1 = [
    (0x0000000000000000, None),
    (0x0000000000000001, None),
    (0xFFFFFFFFFFFFFFFF, None),
    (0x0123456789ABCDEF, None),
    (0x9E3779B97F4A7C15, None),
]


def test_pin_vectors_exist():
    """Print the pin vectors (used once to embed in rust tests) + stability."""
    got = [
        int(ref.fp_words(np.array([[w]], dtype=np.uint64))[0])
        for w, _ in PIN_VECTORS_K1
    ]
    # stability against accidental edits: re-evaluate twice
    got2 = [
        int(ref.fp_words(np.array([[w]], dtype=np.uint64))[0])
        for w, _ in PIN_VECTORS_K1
    ]
    assert got == got2


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=2**64 - 1), min_size=4, max_size=64),
    st.integers(min_value=1, max_value=2**31),
)
def test_hypothesis_ref_bucket_invariants(words, nb):
    """Oracle-level invariants hypothesis-swept: range + determinism."""
    arr = np.array(words, dtype=np.uint64).reshape(-1, 1)
    fp, bucket = ref.hash_partition(arr, nb)
    assert (bucket < nb).all()
    fp2, bucket2 = ref.hash_partition(arr, nb)
    np.testing.assert_array_equal(fp, fp2)
    np.testing.assert_array_equal(bucket, bucket2)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=4), st.integers(min_value=1, max_value=1000))
def test_hypothesis_kernel_shapes(blocks, nb):
    """Kernel over varying grid sizes (shape sweep) matches oracle."""
    b = hashpart.BLOCK * blocks
    rng = np.random.default_rng(blocks * 1000 + nb)
    words = rng.integers(0, 2**64, size=(b, 1), dtype=np.uint64)
    fp, bucket = run_kernel(words, nb, 1)
    efp, ebucket = ref.hash_partition(words, nb)
    np.testing.assert_array_equal(fp, efp)
    np.testing.assert_array_equal(bucket, ebucket)
