"""Deterministic fallback for the `hypothesis` API subset these tests use.

The offline test image has no `hypothesis` wheel; rather than skip the
property tests we run them against seeded pseudo-random cases (no
shrinking). Supports:

- ``@settings(max_examples=N, deadline=None)``
- ``@given(st.integers(...), st.lists(st.integers(...), ...))``

Reproduce a failing run by exporting ``ROOMY_PROP_SEED``.
"""


import os
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


class strategies:  # noqa: N801 - mirrors `hypothesis.strategies` usage
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 20

        def draw(rng):
            k = rng.randint(min_size, hi)
            return [elements._draw(rng) for _ in range(k)]

        return _Strategy(draw)


def settings(max_examples=20, deadline=None, **_ignored):  # noqa: ARG001
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        def wrapper():
            max_examples = getattr(wrapper, "_fallback_max_examples", 20)
            base = int(os.environ.get("ROOMY_PROP_SEED", "3407"))
            for case in range(max_examples):
                rng = random.Random(base + case * 9973)
                drawn = [s._draw(rng) for s in strats]
                try:
                    fn(*drawn)
                except Exception:
                    print(
                        f"property case {case} failed with seed {base} "
                        f"(args {drawn!r}); rerun with ROOMY_PROP_SEED={base}"
                    )
                    raise

        # Keep the collected test name, but do NOT expose the wrapped
        # signature (pytest would mistake drawn params for fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
