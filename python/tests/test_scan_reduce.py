"""scan + reduce Pallas kernels vs pure-numpy oracles."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: deterministic seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from compile.kernels import ref, scan
from compile.kernels import reduce as reduce_k


def test_scan_basic():
    b = scan.BLOCK * 4
    x = np.arange(b, dtype=np.int64)
    y, total = scan.scan_i64(jnp.asarray(x), batch=b)
    expect = ref.scan_i64(x)
    np.testing.assert_array_equal(np.asarray(y), expect)
    assert int(total[0]) == int(expect[-1])


def test_scan_negative_and_zero():
    b = scan.BLOCK
    x = np.zeros(b, dtype=np.int64)
    x[::3] = -5
    x[1::3] = 7
    y, total = scan.scan_i64(jnp.asarray(x), batch=b)
    np.testing.assert_array_equal(np.asarray(y), ref.scan_i64(x))
    assert int(total[0]) == int(x.sum())


def test_scan_carry_across_blocks():
    """Values concentrated in block 0 must appear in later blocks' prefix."""
    b = scan.BLOCK * 3
    x = np.zeros(b, dtype=np.int64)
    x[0] = 1_000_000
    y, _ = scan.scan_i64(jnp.asarray(x), batch=b)
    assert int(y[-1]) == 1_000_000
    assert int(y[scan.BLOCK]) == 1_000_000  # carry reached block 1


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_scan(blocks, seed):
    b = scan.BLOCK * blocks
    rng = np.random.default_rng(seed)
    x = rng.integers(-(2**40), 2**40, size=b, dtype=np.int64)
    y, total = scan.scan_i64(jnp.asarray(x), batch=b)
    expect = ref.scan_i64(x)
    np.testing.assert_array_equal(np.asarray(y), expect)
    assert int(total[0]) == int(expect[-1])


def test_reduce_basic():
    b = reduce_k.BLOCK * 2
    x = np.arange(-10, b - 10, dtype=np.int64)
    sumsq, mn, mx = reduce_k.reduce_i64(jnp.asarray(x), batch=b)
    esumsq, emn, emx = ref.reduce_i64(x)
    assert int(sumsq[0]) == int(esumsq)
    assert int(mn[0]) == int(emn)
    assert int(mx[0]) == int(emx)


def test_reduce_wrapping():
    """Sum of squares wraps like Rust wrapping arithmetic, not saturating."""
    b = reduce_k.BLOCK
    x = np.full(b, 2**31, dtype=np.int64)  # squares are 2^62: sum wraps
    sumsq, _, _ = reduce_k.reduce_i64(jnp.asarray(x), batch=b)
    esumsq, _, _ = ref.reduce_i64(x)
    assert int(sumsq[0]) == int(esumsq)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_reduce(blocks, seed):
    b = reduce_k.BLOCK * blocks
    rng = np.random.default_rng(seed)
    x = rng.integers(-(2**62), 2**62, size=b, dtype=np.int64)
    sumsq, mn, mx = reduce_k.reduce_i64(jnp.asarray(x), batch=b)
    esumsq, emn, emx = ref.reduce_i64(x)
    assert int(sumsq[0]) == int(esumsq)
    assert int(mn[0]) == int(emn)
    assert int(mx[0]) == int(emx)
