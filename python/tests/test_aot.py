"""AOT exporter contract: HLO text artifacts + manifest (the files the
Rust runtime consumes)."""

import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_shape_sig_format():
    import jax

    args = (
        jax.ShapeDtypeStruct((4, 2), jax.numpy.uint64),
        jax.ShapeDtypeStruct((1,), jax.numpy.int32),
    )
    assert aot.shape_sig(args) == "uint64[4,2];int32[1]"


def test_entry_point_names_are_stable():
    names = set(model.entry_points())
    expected = {
        "hash_partition_k1",
        "hash_partition_k2",
        "prefix_scan",
        "reduce_sumsq",
    } | {f"bfs_expand_n{n}" for n in model.PANCAKE_NS}
    assert names == expected


def test_to_hlo_text_produces_entry_computation():
    import jax

    name, (fn, ex_args) = sorted(model.entry_points().items())[0]
    lowered = jax.jit(fn).lower(*ex_args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text, "HLO text must contain an entry computation"
    assert "HloModule" in text


def test_exporter_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    # export a single small entry point to keep the test fast
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--outdir",
            str(out),
            "--only",
            "prefix_scan",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = (out / "manifest.tsv").read_text().strip().splitlines()
    assert len(manifest) == 1
    name, fname, sig = manifest[0].split("\t")
    assert name == "prefix_scan"
    assert (out / fname).exists()
    assert sig.startswith("int64[")


def test_exporter_rejects_unknown_entry(tmp_path):
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--outdir",
            str(tmp_path),
            "--only",
            "not_a_kernel",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert proc.returncode != 0
    assert "unknown entry points" in proc.stderr


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")),
    reason="artifacts not built",
)
def test_built_manifest_lists_all_entry_points():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")
    rows = [l.split("\t") for l in open(path).read().strip().splitlines()]
    names = {r[0] for r in rows}
    assert names == set(model.entry_points())
    art_dir = os.path.dirname(path)
    for _, fname, _ in rows:
        assert os.path.exists(os.path.join(art_dir, fname)), fname
