//! End-to-end driver: the paper's flagship experiment.
//!
//! Solves the pancake sorting problem for n = 9 (362,880 states) by
//! disk-based breadth-first search, exercising every layer of the stack:
//!
//! - L3 Rust coordinator: RoomyList frontier, hash-sharded shuffle,
//!   external-sort dedup (`removeDupes`), sorted-merge `removeAll`;
//! - L1/L2 via PJRT: the fused `bfs_expand_n9` artifact (Pallas packed
//!   prefix-reversal kernel + fingerprint/bucket routing) when
//!   `artifacts/` is present, bit-exact Rust fallback otherwise;
//! - validation: level counts against an in-RAM reference BFS and the
//!   known pancake number f(9) = 10.
//!
//! Reported: per-level counts, wall time, aggregate disk traffic and
//! throughput, per-phase breakdown. EXPERIMENTS.md records a run.
//!
//! Run: `cargo run --release --example pancake_bfs [n] [workers] [checkpoint-dir]`
//!
//! With a third argument the run checkpoints after every BFS level and
//! **resumes** from the last completed level if the directory already
//! holds a checkpoint — kill it mid-run and re-run the same command line
//! to watch it continue (the crash-recovery walkthrough in the README).

use std::time::Instant;

use roomy::accel::Accel;
use roomy::apps::pancake::{self, Structure};
use roomy::constructs::bfs::{BfsOutcome, ResumableBfs};
use roomy::metrics::{fmt_bytes, fmt_rate};
use roomy::{Roomy, RoomyConfig};

fn main() -> roomy::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(9);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let checkpoint_dir = args.get(2).map(std::path::PathBuf::from);
    assert!((2..=11).contains(&n), "n must be in 2..=11");

    let mut cfg = RoomyConfig::default();
    cfg.workers = workers;
    cfg.buckets_per_worker = 4;
    cfg.root = std::env::temp_dir().join(format!("roomy-pancake-{}", std::process::id()));
    cfg.checkpoint_dir = checkpoint_dir.clone();
    let r = Roomy::open(cfg)?;
    let accel = Accel::from_roomy(&r);

    println!("== Pancake sorting by disk-based BFS (paper §3) ==");
    println!(
        "n={n} ({} states) | {} simulated nodes, {} buckets | expansion: {}",
        pancake::factorial(n),
        workers,
        r.cluster().nbuckets(),
        if accel.is_xla() { "XLA AOT kernel (Pallas bfs_expand)" } else { "Rust fallback" },
    );

    // --- the disk-based run -----------------------------------------
    let t0 = Instant::now();
    let stats = if checkpoint_dir.is_some() {
        let mgr = r.checkpoints()?;
        let tag = format!("pancake{n}-list");
        if mgr.exists(&tag) {
            println!("resuming checkpoint {tag:?} under {:?}", mgr.root());
        } else {
            println!("checkpointing every level as {tag:?} under {:?}", mgr.root());
        }
        match pancake::roomy_bfs_resumable(
            &r,
            n,
            Structure::List,
            &accel,
            &ResumableBfs::new(&mgr, tag),
        )? {
            BfsOutcome::Complete(stats) => {
                println!("{}", mgr.stats().snapshot().report());
                stats
            }
            BfsOutcome::Suspended { .. } => unreachable!("no stop hook set"),
        }
    } else {
        pancake::roomy_bfs(&r, n, Structure::List, &accel)?
    };
    let wall = t0.elapsed().as_secs_f64();

    // --- RAM reference baseline --------------------------------------
    let t1 = Instant::now();
    let reference = pancake::reference_bfs(n);
    let ram_wall = t1.elapsed().as_secs_f64();

    println!("\nlevel  roomy      reference");
    let mut ok = true;
    for i in 0..stats.levels.len().max(reference.len()) {
        let a = stats.levels.get(i).copied().unwrap_or(0);
        let b = reference.get(i).copied().unwrap_or(0);
        ok &= a == b;
        println!("{i:>5}  {a:<10} {b}");
    }
    println!("\ntotal states: {} (n! = {})", stats.total, pancake::factorial(n));
    println!("pancake number f({n}) = {}", stats.depth());
    if let Some(known) = pancake::pancake_number(n) {
        ok &= stats.depth() == known && stats.total == pancake::factorial(n);
        println!("known f({n}) = {known}");
    }
    println!("validation: {}", if ok { "OK — exact match" } else { "MISMATCH" });

    let io = r.io_snapshot();
    println!(
        "\nroomy wall {wall:.2}s (RAM reference {ram_wall:.2}s) | \
         disk read {} written {} | aggregate {}",
        fmt_bytes(io.bytes_read),
        fmt_bytes(io.bytes_written),
        fmt_rate(io.bytes_total(), wall),
    );
    println!("\nphase breakdown:\n{}", r.cluster().phases().report());
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
