//! Quickstart: the Roomy API in five minutes.
//!
//! Creates a simulated 4-node cluster over temp directories, then walks
//! through the paper's Table 1: delayed `update`/`access` + `sync` on a
//! RoomyArray, delayed `insert`/`update` on a RoomyHashTable, delayed
//! `add` + immediate set algebra on RoomyLists, and `map`/`reduce`/
//! `predicateCount` everywhere.
//!
//! Run: `cargo run --release --example quickstart`

use roomy::{Roomy, RoomyConfig};

fn main() -> roomy::Result<()> {
    let root = std::env::temp_dir().join(format!("roomy-quickstart-{}", std::process::id()));
    let mut cfg = RoomyConfig::default();
    cfg.workers = 4; // four simulated nodes, each with a "local disk"
    cfg.buckets_per_worker = 2; // 8 buckets per structure
    cfg.root = root.clone();
    let r = Roomy::open(cfg)?;

    // ---------------------------------------------------------------
    // RoomyArray: delayed random access, applied in batch at sync().
    // ---------------------------------------------------------------
    let ra = r.array::<u64>("counts", 1_000, 0)?;
    let inc = ra.register_update(|_i, v: &mut u64, amount: &u64| *v += amount);
    for i in 0..10_000u64 {
        ra.update(i % 1_000, &1u64, inc)?; // delayed — nothing hits disk rows yet
    }
    ra.sync()?; // one streaming pass applies all 10k updates
    println!("counts[0] = {} (expect 10)", ra.fetch(0)?);

    let nonzero = ra.register_predicate(|_i, v| *v > 0)?;
    println!("nonzero cells = {} (maintained, no scan)", ra.predicate_count(nonzero));

    let total = ra.reduce(|| 0u64, |acc, _i, v| acc + v, |a, b| a + b)?;
    println!("reduce sum = {total} (expect 10000)");

    // ---------------------------------------------------------------
    // RoomyHashTable: insert-if-absent via update functions.
    // ---------------------------------------------------------------
    let ht = r.hash_table::<u64, u32>("first_seen")?;
    let first = ht.register_update(|_k, cur: Option<&u32>, round: &u32| {
        Some(cur.copied().unwrap_or(*round))
    });
    for round in 1..=3u32 {
        for k in 0..(round as u64 * 10) {
            ht.update(&k, &round, first)?;
        }
        ht.sync()?;
    }
    println!("first_seen(5) = {:?} (expect Some(1))", ht.fetch(&5)?);
    println!("first_seen(25) = {:?} (expect Some(3))", ht.fetch(&25)?);

    // ---------------------------------------------------------------
    // RoomyList: multiset + set algebra (paper §3 fragments).
    // ---------------------------------------------------------------
    let a = r.list::<u64>("a")?;
    let b = r.list::<u64>("b")?;
    for v in 0..100u64 {
        a.add(&(v % 60))?; // duplicates beyond 40
        b.add(&(v % 50 + 30))?;
    }
    a.sync()?;
    b.sync()?;
    roomy::constructs::setops::to_set(&a)?; // removeDupes
    roomy::constructs::setops::to_set(&b)?;
    let c = roomy::constructs::setops::intersection(&r, "c", &a, &b)?;
    println!("|A|={} |B|={} |A∩B|={} (expect 60 50 30)", a.size(), b.size(), c.size());

    // ---------------------------------------------------------------
    // Where did the bytes go? Every node disk streams in parallel.
    // ---------------------------------------------------------------
    println!("\n{}", r.report());
    println!("disk directories under {root:?} (one per simulated node)");
    Ok(())
}
