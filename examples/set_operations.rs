//! Set algebra on disk-resident lists (paper §3 "Set Operations").
//!
//! Builds two large multisets, converts them to sets, and runs union,
//! difference, and both intersection variants (the paper's
//! union-minus-differences construction and the sorted-merge primitive the
//! paper lists as future work), validating against in-RAM sets and
//! reporting how the external sorts dominate the cost — the paper's
//! RoomyList performance caveat.
//!
//! Run: `cargo run --release --example set_operations [elements]`

use std::collections::BTreeSet;
use std::time::Instant;

use roomy::constructs::setops;
use roomy::metrics::fmt_bytes;
use roomy::{Roomy, RoomyConfig};

fn main() -> roomy::Result<()> {
    let n: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);

    let mut cfg = RoomyConfig::default();
    cfg.workers = 4;
    cfg.root = std::env::temp_dir().join(format!("roomy-setops-{}", std::process::id()));
    let r = Roomy::open(cfg)?;

    println!("== Roomy set operations over {n} elements/side ==");
    let a = r.list::<u64>("A")?;
    let b = r.list::<u64>("B")?;
    // A = multiples of 2 below 3n (with duplicates); B = multiples of 3
    for i in 0..n {
        a.add(&(2 * i % (3 * n / 2)))?;
        b.add(&(3 * i % (2 * n)))?;
    }
    a.sync()?;
    b.sync()?;
    println!("built: |A|={} |B|={} (multisets)", a.size(), b.size());

    let t = Instant::now();
    setops::to_set(&a)?;
    setops::to_set(&b)?;
    println!("removeDupes (external sort): {:.2}s -> |A|={} |B|={}",
        t.elapsed().as_secs_f64(), a.size(), b.size());

    // model sets for validation
    let sa: BTreeSet<u64> = a.collect()?.into_iter().collect();
    let sb: BTreeSet<u64> = b.collect()?.into_iter().collect();

    let t = Instant::now();
    let c1 = setops::intersection(&r, "C1", &a, &b)?;
    let t1 = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let c2 = setops::intersection_primitive(&r, "C2", &a, &b)?;
    let t2 = t.elapsed().as_secs_f64();
    let expect: BTreeSet<u64> = sa.intersection(&sb).copied().collect();
    let got1: BTreeSet<u64> = c1.collect()?.into_iter().collect();
    let got2: BTreeSet<u64> = c2.collect()?.into_iter().collect();
    println!(
        "intersection: paper construction {t1:.2}s, primitive {t2:.2}s, |A∩B|={}",
        c1.size()
    );
    assert_eq!(got1, expect, "paper intersection mismatch");
    assert_eq!(got2, expect, "primitive intersection mismatch");

    let t = Instant::now();
    let union = r.list::<u64>("U")?;
    union.add_all(&a)?;
    setops::union_into(&union, &b)?;
    println!("union: {:.2}s, |A∪B|={}", t.elapsed().as_secs_f64(), union.size());
    let eu: BTreeSet<u64> = sa.union(&sb).copied().collect();
    assert_eq!(union.size(), eu.len() as u64);

    let t = Instant::now();
    setops::difference_into(&a, &b)?;
    println!("difference: {:.2}s, |A-B|={}", t.elapsed().as_secs_f64(), a.size());
    let ed: BTreeSet<u64> = sa.difference(&sb).copied().collect();
    assert_eq!(a.size(), ed.len() as u64);

    // ---- the paper's future work: native RoomySet ------------------
    println!("\n== native RoomySet (paper future work) ==");
    let sa2 = r.set::<u64>("SA")?;
    let sb2 = r.set::<u64>("SB")?;
    for v in sa.iter() {
        sa2.add(v)?;
    }
    for v in sb.iter() {
        sb2.add(v)?;
    }
    sa2.sync()?;
    sb2.sync()?;
    let t = Instant::now();
    sa2.intersect_with(&sb2)?;
    println!(
        "RoomySet::intersect_with: {:.2}s (vs paper construction {t1:.2}s), |A∩B|={}",
        t.elapsed().as_secs_f64(),
        sa2.size()
    );
    assert_eq!(sa2.size(), expect.len() as u64);

    let io = r.io_snapshot();
    println!(
        "\nvalidation OK | disk: read {} written {}\nphases:\n{}",
        fmt_bytes(io.bytes_read),
        fmt_bytes(io.bytes_written),
        r.cluster().phases().report()
    );
    Ok(())
}
