//! Word-count-style aggregation on a RoomyHashTable: the "update a value
//! with a user-defined function" idiom (paper Table 1) at scale.
//!
//! A synthetic Zipf-ish token stream is aggregated with delayed
//! insert-if-absent/increment updates; `sync` applies the whole stream in
//! one pass per bucket. The top-k is then extracted with `reduce`, and the
//! histogram cross-checked against an in-RAM HashMap.
//!
//! Run: `cargo run --release --example wordcount [tokens]`

use std::collections::HashMap;
use std::time::Instant;

use roomy::metrics::{fmt_bytes, fmt_rate};
use roomy::{Roomy, RoomyConfig};

/// xorshift-ish token sampler: token ids follow a rough power law.
fn sample_token(state: &mut u64) -> u64 {
    *state ^= *state << 13;
    *state ^= *state >> 7;
    *state ^= *state << 17;
    let u = (*state >> 11) as f64 / (1u64 << 53) as f64;
    // inverse-CDF of a truncated zipf over 10_000 tokens
    ((u.powf(3.0)) * 10_000.0) as u64
}

fn main() -> roomy::Result<()> {
    let tokens: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);

    let mut cfg = RoomyConfig::default();
    cfg.workers = 4;
    cfg.root = std::env::temp_dir().join(format!("roomy-wc-{}", std::process::id()));
    let r = Roomy::open(cfg)?;

    let counts = r.hash_table::<u64, u64>("counts")?;
    let bump = counts
        .register_update(|_k, cur: Option<&u64>, _p: &()| Some(cur.copied().unwrap_or(0) + 1));

    println!("== word count: {tokens} tokens over a 10k vocabulary ==");
    let mut model: HashMap<u64, u64> = HashMap::new();
    let mut state = 0x853C49E6748FEA9Bu64;
    let t0 = Instant::now();
    for _ in 0..tokens {
        let tok = sample_token(&mut state);
        counts.update(&tok, &(), bump)?;
        *model.entry(tok).or_insert(0) += 1;
    }
    let t_stage = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    counts.sync()?;
    let t_sync = t1.elapsed().as_secs_f64();

    println!(
        "staged {tokens} delayed updates in {t_stage:.2}s, applied in {t_sync:.2}s \
         ({:.0} updates/s end-to-end)",
        tokens as f64 / (t_stage + t_sync)
    );
    println!("distinct tokens: {} (model {})", counts.size(), model.len());
    assert_eq!(counts.size(), model.len() as u64);

    // top-5 via reduce
    let top = counts.reduce(
        Vec::new,
        |mut acc: Vec<(u64, u64)>, k, v| {
            acc.push((*v, *k));
            acc.sort_unstable_by(|a, b| b.cmp(a));
            acc.truncate(5);
            acc
        },
        |mut a, b| {
            a.extend(b);
            a.sort_unstable_by(|x, y| y.cmp(x));
            a.truncate(5);
            a
        },
    )?;
    println!("top-5 (count, token): {top:?}");

    // full cross-check
    let bad = counts.reduce(
        || 0u64,
        |acc, k, v| acc + u64::from(model.get(k) != Some(v)),
        |a, b| a + b,
    )?;
    assert_eq!(bad, 0, "histogram must match the in-RAM model exactly");
    println!("validation vs in-RAM model: OK");

    let io = r.io_snapshot();
    println!(
        "\ndisk: read {} written {} | sync throughput {}",
        fmt_bytes(io.bytes_read),
        fmt_bytes(io.bytes_written),
        fmt_rate(io.bytes_total(), t_sync),
    );
    Ok(())
}
