//! Pocket-cube (2×2×2 Rubik's cube) God's-number computation by
//! disk-based BFS — the workload family Roomy was built for (Kunkle &
//! Cooperman's Rubik's-cube results used the same disk-based BFS
//! machinery at 3×3×3 scale).
//!
//! Enumerates all 3 674 160 states (DBL corner fixed, half-turn metric),
//! reports the depth profile, and validates God's number = 11 plus the
//! exact level counts against an in-RAM reference BFS.
//!
//! Run: `cargo run --release --example rubik_bfs [workers]`

use std::time::Instant;

use roomy::accel::Accel;
use roomy::apps::rubik;
use roomy::metrics::{fmt_bytes, fmt_rate};
use roomy::{Roomy, RoomyConfig};

fn main() -> roomy::Result<()> {
    let workers: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let mut cfg = RoomyConfig::default();
    cfg.workers = workers;
    cfg.buckets_per_worker = 4;
    cfg.root = std::env::temp_dir().join(format!("roomy-rubik-{}", std::process::id()));
    let r = Roomy::open(cfg)?;

    println!("== 2x2x2 Rubik's cube by disk-based BFS ==");
    println!(
        "{} states (7! x 3^6), 9 HTM generators, {} simulated nodes",
        rubik::STATE_COUNT,
        workers
    );

    let t0 = Instant::now();
    let stats = rubik::roomy_bfs(&r, &Accel::rust())?;
    let wall = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let reference = rubik::reference_bfs();
    let ram_wall = t1.elapsed().as_secs_f64();

    println!("\ndepth  roomy      reference");
    let mut ok = true;
    for i in 0..stats.levels.len().max(reference.len()) {
        let a = stats.levels.get(i).copied().unwrap_or(0);
        let b = reference.get(i).copied().unwrap_or(0);
        ok &= a == b;
        println!("{i:>5}  {a:<10} {b}");
    }
    ok &= stats.total == rubik::STATE_COUNT && stats.depth() == rubik::GODS_NUMBER;
    println!("\ntotal {} (expect {})", stats.total, rubik::STATE_COUNT);
    println!("God's number (HTM) = {} (known {})", stats.depth(), rubik::GODS_NUMBER);
    println!("validation: {}", if ok { "OK — exact match" } else { "MISMATCH" });

    let io = r.io_snapshot();
    println!(
        "\nroomy wall {wall:.1}s (RAM reference {ram_wall:.1}s) | \
         disk read {} written {} | aggregate {}",
        fmt_bytes(io.bytes_read),
        fmt_bytes(io.bytes_written),
        fmt_rate(io.bytes_total(), wall),
    );
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
