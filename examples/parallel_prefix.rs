//! Chain reduction and parallel prefix (paper §3), with the XLA-accelerated
//! single-pass scan as the three-layer showcase.
//!
//! Compares:
//! 1. the paper's log-round parallel prefix (⌈log2 N⌉ map+sync rounds,
//!    each a full streaming pass over the disks);
//! 2. the accelerated single-pass variant: per-bucket Pallas scan kernel
//!    (AOT via PJRT when artifacts are present) with the carry chained in
//!    the Rust coordinator.
//!
//! Both produce identical bits; the single pass does ~log2(N)× less disk
//! traffic — the E7 ablation in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example parallel_prefix [n]`

use std::time::Instant;

use roomy::accel::Accel;
use roomy::constructs::{chainred, prefix};
use roomy::metrics::fmt_bytes;
use roomy::{Roomy, RoomyConfig};

fn main() -> roomy::Result<()> {
    let n: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);

    let mk = |tag: &str| -> roomy::Result<Roomy> {
        let mut cfg = RoomyConfig::default();
        cfg.workers = 4;
        cfg.root =
            std::env::temp_dir().join(format!("roomy-prefix-{tag}-{}", std::process::id()));
        Roomy::open(cfg)
    };

    println!("== chain reduction (paper example) ==");
    let r0 = mk("chain")?;
    let ra = r0.array::<i64>("a", 32, 0)?;
    ra.map_update(|i, v| *v = i as i64 + 1)?;
    chainred::chain_reduce(&ra, |a, b| a + b)?;
    let head: Vec<i64> = (0..8).map(|i| ra.fetch(i).unwrap()).collect();
    println!("a[i] = old a[i] + old a[i-1]: {head:?}\n");

    println!("== parallel prefix over {n} i64 ==");
    // log-round variant
    let r1 = mk("logrounds")?;
    let ra1 = r1.array::<i64>("p", n, 0)?;
    ra1.map_update(|i, v| *v = (i as i64 % 1000) - 500)?;
    let t = Instant::now();
    prefix::parallel_prefix(&ra1, |a, b| a.wrapping_add(*b))?;
    let t_log = t.elapsed().as_secs_f64();
    let io1 = r1.io_snapshot();

    // single-pass scan-kernel variant
    let r2 = mk("scanpass")?;
    let accel = Accel::from_roomy(&r2);
    let ra2 = r2.array::<i64>("p", n, 0)?;
    ra2.map_update(|i, v| *v = (i as i64 % 1000) - 500)?;
    let before = r2.io_snapshot();
    let t = Instant::now();
    prefix::prefix_scan_array(&ra2, &accel)?;
    let t_scan = t.elapsed().as_secs_f64();
    let io2 = r2.io_snapshot().delta(&before);

    // validate tails agree
    for i in [0, n / 3, n - 1] {
        assert_eq!(ra1.fetch(i)?, ra2.fetch(i)?, "mismatch at {i}");
    }
    println!(
        "log-round construct : {t_log:.3}s, {} moved ({} rounds)",
        fmt_bytes(io1.bytes_total()),
        (64 - (n - 1).leading_zeros()),
    );
    println!(
        "single-pass scan    : {t_scan:.3}s, {} moved (backend: {})",
        fmt_bytes(io2.bytes_total()),
        if accel.is_xla() { "XLA Pallas scan kernel" } else { "Rust" },
    );
    println!("results identical — validation OK");
    Ok(())
}
